//! Persistent executor runtime — the crate's one worker pool.
//!
//! CPSAA keeps every pipeline stage *resident*: crossbars hold their
//! operands, the pruning and attention units run concurrently, and work
//! arrives at standing hardware instead of hardware being built per
//! batch (§4.5). The software analogue is this executor: one long-lived
//! pool of worker threads with a flat task queue, replacing the old
//! model of re-spawning scoped OS threads at every fan-out level (plan
//! scans → row partitions → heads → shards) per batch.
//!
//! ## Execution model
//!
//! * **Flat queue, shared helpers.** A fan-out submits one job holding N
//!   index-claimable tasks. Idle workers *and the submitting thread*
//!   claim task indices from the same job until it is exhausted — the
//!   caller always participates, so a 1-worker executor runs everything
//!   serially on the caller (the determinism leg) and nested fan-outs
//!   (shards → heads → row ranges) flatten into the one pool instead of
//!   oversubscribing the machine with nested spawns.
//! * **Borrowed closures, scope-style.** Tasks may borrow the caller's
//!   stack (matrices, plans, output slices). Safety comes from the same
//!   invariant `std::thread::scope` provides: `map`/`map_consume` do not
//!   return until every claimed task has finished, and a task that was
//!   never claimed never touches the borrowed data.
//! * **Panic propagation.** A panicking task poisons its job (remaining
//!   tasks are skipped), the worker survives, and the submitting call
//!   re-panics with the worker's index and the original message.
//! * **Values are schedule-invariant.** Every task writes only its own
//!   output slot, so results are bit-identical at any worker count —
//!   the invariant the fused-vs-unfused property grid pins.
//! * **Two lanes, high first, stealing both ways.** The queue is split
//!   into a high and a normal lane ([`Lane`]). Idle workers always
//!   drain the high lane before the normal one, and a worker whose high
//!   queue is empty steals from normal rather than sleeping — bulk work
//!   never idles the pool. The preference also holds *mid-job*: a
//!   worker grinding a bulk normal fan-out re-checks the high lane
//!   between task claims and yields back to it the moment a high job
//!   arrives, returning to the normal job afterwards — so interactive
//!   batches are not starved behind an already-started bulk fan-out.
//!   A job inherits the submitting thread's lane ([`current_lane`],
//!   scoped via [`with_lane`]), and helpers adopt the job's lane while
//!   running its tasks — nested fan-outs spawned from inside a
//!   high-lane job land in the high lane too. Lanes reorder *scheduling
//!   only*; values stay schedule-invariant, so bit-identity across
//!   worker counts is unaffected.
//!
//! ## Sizing and the grain heuristic
//!
//! The pool is sized by the `max_kernel_workers` plumbing: the
//! [`global`] executor resolves `CPSAA_MAX_KERNEL_WORKERS` (else 8,
//! capped at the machine's parallelism), and the serving layer's
//! `--max-workers` knob rebuilds it via [`configure`] at startup (0 is
//! rejected). [`Executor::workers_for`] is the one serial-fallback
//! heuristic: work below [`GRAIN`] coordinates/cells never queues —
//! replacing the per-site thresholds the kernels and plan scans used to
//! duplicate.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};

/// Scheduling lane for a submitted fan-out. `High` jobs are drained by
/// idle workers before any `Normal` job — the serving layer routes
/// latency-sensitive batches here so they are not starved behind bulk
/// work. Lanes never change values, only claim order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lane {
    High,
    #[default]
    Normal,
}

impl Lane {
    pub fn as_str(self) -> &'static str {
        match self {
            Lane::High => "high",
            Lane::Normal => "normal",
        }
    }
}

thread_local! {
    static CURRENT_LANE: Cell<Lane> = const { Cell::new(Lane::Normal) };
}

/// The lane new fan-outs from this thread are submitted on.
pub fn current_lane() -> Lane {
    CURRENT_LANE.with(Cell::get)
}

/// Restores the previous lane on drop, so `with_lane` and `Job::help`
/// unwind cleanly even when a task panics.
struct LaneGuard(Lane);

impl Drop for LaneGuard {
    fn drop(&mut self) {
        CURRENT_LANE.with(|c| c.set(self.0));
    }
}

/// Run `f` with this thread's submission lane set to `lane`, restoring
/// the previous lane afterwards (panic-safe). The leader loop wraps each
/// batch execution in this so every nested fan-out (shards → heads →
/// row ranges) inherits the batch's lane.
pub fn with_lane<R>(lane: Lane, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT_LANE.with(|c| c.replace(lane));
    let _restore = LaneGuard(prev);
    f()
}

/// Work below this weight (mask cells, plan coordinates) runs serially
/// on the caller: queueing it costs more than computing it. The one
/// crate-wide serial-fallback threshold.
pub const GRAIN: usize = 1 << 12;

/// Default worker cap when `CPSAA_MAX_KERNEL_WORKERS` is unset (the
/// historical kernel cap).
const DEFAULT_WORKER_CAP: usize = 8;

/// One submitted fan-out: N tasks claimed by index from the flat queue.
/// The type-erased `data`/`runner` pair points into the submitting
/// call's stack; it is only dereferenced between a successful index
/// claim (`next < total`) and the matching `completed` increment — a
/// window the submitter always outlives (it blocks until
/// `completed == total`).
struct Job {
    next: AtomicUsize,
    completed: AtomicUsize,
    total: usize,
    /// The lane this job was submitted on; helpers adopt it while
    /// claiming tasks so nested submissions inherit the priority.
    lane: Lane,
    /// Set by the first panicking task: remaining tasks are skipped.
    poisoned: AtomicBool,
    data: *const (),
    runner: unsafe fn(*const (), usize),
    done: Mutex<()>,
    done_cv: Condvar,
    /// First panic observed: (worker label, panic message).
    panic: Mutex<Option<(String, String)>>,
}

// The raw pointers are only dereferenced under the claim protocol above,
// and every pointee is Sync-accessible per claimed index.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// True once every task index has been handed out (the job can be
    /// dropped from the queue; late claimers become no-ops).
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.total
    }

    /// Claim and run task indices until none remain. `label` identifies
    /// the helping thread in panic reports. The helper adopts the job's
    /// lane for the duration, so fan-outs submitted from inside a task
    /// queue at the same priority as the job itself.
    fn help(&self, label: &str) {
        self.help_while(label, || true);
    }

    /// [`Job::help`] with a yield point: between task claims, return to
    /// the caller as soon as `keep_going` turns false, leaving remaining
    /// tasks unclaimed for other helpers. The worker loop passes
    /// "no high job pending" here for normal-lane jobs, so a bulk
    /// fan-out can be preempted at task granularity. Claimed tasks
    /// always run to completion — yielding never abandons work mid-task.
    fn help_while(&self, label: &str, keep_going: impl Fn() -> bool) {
        let prev = CURRENT_LANE.with(|c| c.replace(self.lane));
        let _restore = LaneGuard(prev);
        loop {
            if !keep_going() {
                return;
            }
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                return;
            }
            if !self.poisoned.load(Ordering::Relaxed) {
                let run = catch_unwind(AssertUnwindSafe(|| unsafe { (self.runner)(self.data, i) }));
                if let Err(payload) = run {
                    self.poisoned.store(true, Ordering::Relaxed);
                    let msg = panic_message(&payload);
                    let mut slot = self.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some((label.to_string(), msg));
                    }
                }
            }
            // Release pairs with the submitter's Acquire wait: the task's
            // output writes happen-before the submitter sees the count.
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
                let _sync = self.done.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }

    /// Block until every claimed task has finished.
    fn wait(&self) {
        let mut guard = self.done.lock().unwrap();
        while self.completed.load(Ordering::Acquire) < self.total {
            guard = self.done_cv.wait(guard).unwrap();
        }
    }
}

/// Best-effort string form of a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct PoolState {
    /// Jobs submitted on [`Lane::High`]; always drained first.
    high: VecDeque<Arc<Job>>,
    /// Jobs submitted on [`Lane::Normal`].
    normal: VecDeque<Arc<Job>>,
    shutdown: bool,
}

impl PoolState {
    fn lane_queue(&mut self, lane: Lane) -> &mut VecDeque<Arc<Job>> {
        match lane {
            Lane::High => &mut self.high,
            Lane::Normal => &mut self.normal,
        }
    }
}

struct Shared {
    state: Mutex<PoolState>,
    available: Condvar,
    /// High-lane jobs currently queued (exhausted-but-unpopped included;
    /// the next worker pass cleans those up). Updated under the `state`
    /// lock, read lock-free by normal-lane helpers deciding whether to
    /// yield back to the high lane between task claims.
    high_pending: AtomicUsize,
}

/// The long-lived worker pool. One instance serves the whole crate (see
/// [`global`]); tests and the engine may hold their own for injection.
pub struct Executor {
    shared: Arc<Shared>,
    workers: usize,
}

impl Executor {
    /// A pool with `workers` total concurrency — the submitting thread
    /// counts as one, so `workers - 1` background threads are spawned
    /// and `workers == 1` is the strictly serial executor.
    ///
    /// # Panics
    /// If `workers == 0` (serving rejects 0 at startup; a zero-width
    /// pool could make no progress).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "executor needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                shutdown: false,
            }),
            available: Condvar::new(),
            high_pending: AtomicUsize::new(0),
        });
        for index in 0..workers - 1 {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name(format!("cpsaa-exec-{index}"))
                .spawn(move || worker_loop(shared, index))
                .expect("spawning executor worker");
        }
        Self { shared, workers }
    }

    /// Total concurrency (background threads + the participating caller).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers a fan-out of `weight` (coordinates, cells) should use:
    /// 1 below [`GRAIN`] — small work never queues — else the pool
    /// width. The one serial-fallback heuristic every kernel shares.
    pub fn workers_for(&self, weight: usize) -> usize {
        if weight < GRAIN {
            1
        } else {
            self.workers
        }
    }

    /// Map `f` over `items` on the pool, order-preserving. Serial (on
    /// the caller, schedule-identical to a plain `iter().map`) when the
    /// input has ≤ 1 item or the pool has one worker. Propagates the
    /// first task panic with the helping worker's label.
    pub fn map<T: Sync, R: Send>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
        if items.len() <= 1 || self.workers == 1 {
            return items.iter().map(f).collect();
        }
        self.map_consume(items.iter().collect::<Vec<&T>>(), f)
    }

    /// [`Executor::map`] over owned items — the variant the row-range
    /// dispatchers use to hand each task exclusive `&mut` output slices.
    pub fn map_consume<T: Send, R: Send, F: Fn(T) -> R + Sync>(
        &self,
        items: Vec<T>,
        f: F,
    ) -> Vec<R> {
        let total = items.len();
        if total <= 1 || self.workers == 1 {
            return items.into_iter().map(f).collect();
        }

        // Per-index slots: a claim hands exactly one task to exactly one
        // helper, so the unsynchronized slot access never aliases.
        let mut items: Vec<Option<T>> = items.into_iter().map(Some).collect();
        let mut results: Vec<Option<R>> = (0..total).map(|_| None).collect();

        struct Ctx<'a, T, R, F> {
            items: *mut Option<T>,
            results: *mut Option<R>,
            f: &'a F,
        }
        unsafe fn run_one<T, R, F: Fn(T) -> R>(data: *const (), i: usize) {
            let ctx = &*data.cast::<Ctx<'_, T, R, F>>();
            let item = (*ctx.items.add(i)).take().expect("task index claimed twice");
            let out = (ctx.f)(item);
            *ctx.results.add(i) = Some(out);
        }

        let ctx = Ctx { items: items.as_mut_ptr(), results: results.as_mut_ptr(), f: &f };
        let lane = current_lane();
        let job = Arc::new(Job {
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            total,
            lane,
            poisoned: AtomicBool::new(false),
            data: &ctx as *const Ctx<'_, T, R, F> as *const (),
            runner: run_one::<T, R, F>,
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });

        // Enqueue for the pool, then work the job from this thread too.
        {
            let mut state = self.shared.state.lock().unwrap();
            state.lane_queue(lane).push_back(job.clone());
            if lane == Lane::High {
                self.shared.high_pending.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.shared.available.notify_all();
        job.help("caller");
        job.wait();

        if let Some((label, msg)) = job.panic.lock().unwrap().take() {
            panic!("executor worker {label} panicked: {msg}");
        }
        results.into_iter().map(|r| r.expect("claimed task left no result")).collect()
    }

    /// Submit a single detached task and return immediately — the
    /// fire-and-forget primitive behind plan prefetch. The task is a
    /// one-index [`Job`] on the submitting thread's lane, run by the
    /// first idle worker; the caller keeps driving its own work (the
    /// overlap) and collects the result later via [`JoinHandle::join`].
    /// A 1-worker pool degrades gracefully: nobody picks the job up, so
    /// it runs on the joining thread — correct, just with no overlap.
    ///
    /// Unlike `map`/`map_consume`, the closure is `'static`: it owns its
    /// inputs (the prefetch path clones the payload), because the
    /// submitting call returns while the task may still be queued.
    pub fn spawn<R: Send + 'static>(
        &self,
        f: impl FnOnce() -> R + Send + 'static,
    ) -> JoinHandle<R> {
        let ctx: Box<SpawnCtx<R>> = Box::new(SpawnCtx {
            f: Mutex::new(Some(Box::new(f))),
            out: Mutex::new(None),
        });
        unsafe fn run_spawned<R>(data: *const (), _i: usize) {
            let ctx = &*data.cast::<SpawnCtx<R>>();
            let f = ctx.f.lock().unwrap().take().expect("spawned task claimed twice");
            let out = f();
            *ctx.out.lock().unwrap() = Some(out);
        }
        let lane = current_lane();
        let job = Arc::new(Job {
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            total: 1,
            lane,
            poisoned: AtomicBool::new(false),
            data: &*ctx as *const SpawnCtx<R> as *const (),
            runner: run_spawned::<R>,
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut state = self.shared.state.lock().unwrap();
            state.lane_queue(lane).push_back(job.clone());
            if lane == Lane::High {
                self.shared.high_pending.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.shared.available.notify_all();
        JoinHandle { job, ctx }
    }

    /// Current (high, normal) queue lengths, exhausted jobs included —
    /// test instrumentation for the lane-ordering harness.
    #[cfg(test)]
    fn queue_depths(&self) -> (usize, usize) {
        let state = self.shared.state.lock().unwrap();
        (state.high.len(), state.normal.len())
    }
}

/// Heap context of one [`Executor::spawn`] task: the closure before the
/// run, the result after. Owned by the [`JoinHandle`]; the job's raw
/// `data` pointer targets this box, which outlives every worker access
/// because the handle's drop blocks until the task has completed.
struct SpawnCtx<R> {
    f: Mutex<Option<Box<dyn FnOnce() -> R + Send>>>,
    out: Mutex<Option<R>>,
}

/// Handle to a detached [`Executor::spawn`] task. [`JoinHandle::join`]
/// returns the task's value (running it on the joining thread if no
/// worker claimed it yet) and re-raises the task's panic, mirroring
/// `map`'s propagation. Dropping without joining still waits for the
/// task — the scope-style safety invariant, kept even for detached work.
pub struct JoinHandle<R> {
    job: Arc<Job>,
    ctx: Box<SpawnCtx<R>>,
}

impl<R> JoinHandle<R> {
    /// Block until the task has run — claiming it on this thread if it
    /// is still queued — and return its value.
    pub fn join(self) -> R {
        self.job.help("joiner");
        self.job.wait();
        if let Some((label, msg)) = self.job.panic.lock().unwrap().take() {
            panic!("executor worker {label} panicked: {msg}");
        }
        self.ctx.out.lock().unwrap().take().expect("spawned task left no result")
    }
}

impl<R> Drop for JoinHandle<R> {
    fn drop(&mut self) {
        // `join` consumed the panic slot already when it ran; a bare
        // drop just ensures the task is finished before the ctx frees.
        self.job.help("joiner");
        self.job.wait();
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let mut state = self.shared.state.lock().unwrap();
        state.shutdown = true;
        drop(state);
        self.shared.available.notify_all();
    }
}

/// Background worker: take the front job — high lane before normal,
/// stealing from normal when high is empty — and help until it is
/// exhausted, repeat. Jobs stay at the front while unexhausted so
/// *every* idle worker piles onto the same fan-out (the flat-queue
/// invariant, now per lane). Normal-lane jobs are helped through the
/// yield point: the worker returns to the queue as soon as a high job
/// is pending, runs it, and then resumes the (still-queued) normal job.
fn worker_loop(shared: Arc<Shared>, index: usize) {
    let label = index.to_string();
    loop {
        let job = {
            let mut state = shared.state.lock().unwrap();
            loop {
                while state.high.front().is_some_and(|j| j.exhausted()) {
                    state.high.pop_front();
                    shared.high_pending.fetch_sub(1, Ordering::Relaxed);
                }
                while state.normal.front().is_some_and(|j| j.exhausted()) {
                    state.normal.pop_front();
                }
                if state.shutdown {
                    return;
                }
                if let Some(job) = state.high.front().or_else(|| state.normal.front()) {
                    break job.clone();
                }
                state = shared.available.wait(state).unwrap();
            }
        };
        match job.lane {
            Lane::High => job.help(&label),
            Lane::Normal => {
                job.help_while(&label, || shared.high_pending.load(Ordering::Relaxed) == 0)
            }
        }
    }
}

fn global_cell() -> &'static RwLock<Arc<Executor>> {
    static CELL: OnceLock<RwLock<Arc<Executor>>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(Arc::new(Executor::new(default_workers()))))
}

/// The crate-wide executor every fan-out shares (kernels, plan scans,
/// head/shard dispatch, all leader threads). Built lazily from
/// [`default_workers`]; replaced wholesale by [`configure`].
pub fn global() -> Arc<Executor> {
    global_cell().read().unwrap().clone()
}

/// Rebuild the global pool at `workers` total concurrency — the
/// `ServiceConfig::max_kernel_workers` / `serve --max-workers` startup
/// knob. Rejects 0. Executions already holding the old pool finish on
/// it; it drains and stops when the last handle drops.
pub fn configure(workers: usize) -> Result<(), String> {
    if workers == 0 {
        return Err("executor workers must be >= 1".into());
    }
    *global_cell().write().unwrap() = Arc::new(Executor::new(workers));
    Ok(())
}

/// Pool width when nothing configures one: `CPSAA_MAX_KERNEL_WORKERS`
/// (ignored unless > 0), else 8, never above the machine's parallelism.
pub fn default_workers() -> usize {
    let cap = std::env::var("CPSAA_MAX_KERNEL_WORKERS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(DEFAULT_WORKER_CAP);
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(cap).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_values() {
        let exec = Executor::new(4);
        let items: Vec<usize> = (0..64).collect();
        let out = exec.map(&items, |&x| x * x);
        assert_eq!(out, items.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let exec = Executor::new(4);
        let none: [u32; 0] = [];
        assert!(exec.map(&none, |&x| x).is_empty());
        assert!(exec.map_consume(Vec::<u32>::new(), |x| x).is_empty());
        // A single item runs inline on the caller — schedule-identical
        // to a serial call (the heads=1 bit-equivalence invariant).
        let here = std::thread::current().id();
        let out = exec.map(&[7usize], |&x| {
            assert_eq!(std::thread::current().id(), here);
            x + 1
        });
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn single_worker_pool_runs_serially_on_caller() {
        let exec = Executor::new(1);
        let here = std::thread::current().id();
        let items: Vec<usize> = (0..16).collect();
        let out = exec.map(&items, |&x| {
            assert_eq!(std::thread::current().id(), here, "workers=1 must never hop threads");
            x + 1
        });
        assert_eq!(out, (1..17).collect::<Vec<_>>());
    }

    #[test]
    fn map_consume_hands_out_exclusive_items() {
        let exec = Executor::new(4);
        let mut data = vec![0u64; 32];
        let tasks: Vec<(usize, &mut [u64])> =
            data.chunks_mut(8).enumerate().collect();
        exec.map_consume(tasks, |(k, chunk)| {
            for (j, slot) in chunk.iter_mut().enumerate() {
                *slot = (k * 8 + j) as u64;
            }
        });
        assert_eq!(data, (0..32u64).collect::<Vec<_>>());
    }

    #[test]
    fn results_identical_at_any_worker_count() {
        let items: Vec<usize> = (0..40).collect();
        let want: Vec<usize> = items.iter().map(|&x| x.wrapping_mul(2654435761)).collect();
        for workers in [1, 2, 3, 8] {
            let exec = Executor::new(workers);
            let got = exec.map(&items, |&x| x.wrapping_mul(2654435761));
            assert_eq!(got, want, "diverged at {workers} workers");
        }
    }

    #[test]
    #[should_panic(expected = "executor worker")]
    fn panic_carries_worker_index() {
        let exec = Executor::new(4);
        let items: Vec<usize> = (0..8).collect();
        exec.map(&items, |_| panic!("boom"));
    }

    #[test]
    fn panic_poisons_job_but_pool_survives() {
        let exec = Executor::new(4);
        let ran = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        let blast = catch_unwind(AssertUnwindSafe(|| {
            exec.map(&items, |&i| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 0 {
                    panic!("boom");
                }
                i
            })
        }));
        let msg = panic_message(&blast.expect_err("panic must propagate"));
        assert!(msg.contains("executor worker") && msg.contains("boom"), "{msg}");
        // Poisoning skipped the tail of the job...
        assert!(ran.load(Ordering::Relaxed) <= 64);
        // ...and the pool still serves new jobs afterwards.
        let out = exec.map(&items, |&i| i + 1);
        assert_eq!(out.len(), 64);
        assert_eq!(out[63], 64);
    }

    #[test]
    fn nested_submits_flatten_into_the_pool() {
        // workers=2 ⇒ at most two threads exist to run tasks (caller +
        // one background worker). An outer map whose tasks submit inner
        // maps must complete (caller participation ⇒ no deadlock) and
        // never exceed the pool's concurrency.
        let exec = Executor::new(2);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let outer: Vec<usize> = (0..4).collect();
        let sums = exec.map(&outer, |&o| {
            let inner: Vec<usize> = (0..4).collect();
            let vals = exec.map(&inner, |&i| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(2));
                live.fetch_sub(1, Ordering::SeqCst);
                o * 10 + i
            });
            vals.iter().sum::<usize>()
        });
        assert_eq!(sums, vec![6, 46, 86, 126]);
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "nested fan-out oversubscribed: peak {}",
            peak.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn grain_heuristic_gates_small_work() {
        let exec = Executor::new(8);
        assert_eq!(exec.workers_for(0), 1);
        assert_eq!(exec.workers_for(GRAIN - 1), 1);
        assert_eq!(exec.workers_for(GRAIN), 8);
        assert_eq!(exec.workers_for(usize::MAX), 8);
        let serial = Executor::new(1);
        assert_eq!(serial.workers_for(usize::MAX), 1);
    }

    #[test]
    fn configure_rejects_zero_and_resizes() {
        assert!(configure(0).is_err());
        let before = global().workers();
        configure(2).unwrap();
        assert_eq!(global().workers(), 2);
        // Values are schedule-invariant, so restoring is safe even with
        // concurrent tests mid-flight on the old pool.
        configure(before).unwrap();
        assert_eq!(global().workers(), before);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = global();
        let b = global();
        assert!(Arc::ptr_eq(&a, &b) || a.workers() == b.workers());
        assert!(a.workers() >= 1);
    }

    #[test]
    fn with_lane_scopes_and_restores() {
        assert_eq!(current_lane(), Lane::Normal);
        assert_eq!(with_lane(Lane::High, current_lane), Lane::High);
        assert_eq!(current_lane(), Lane::Normal);
        // Restores across a panic too (the guard is drop-based).
        let blast = catch_unwind(AssertUnwindSafe(|| {
            with_lane(Lane::High, || panic!("boom"))
        }));
        assert!(blast.is_err());
        assert_eq!(current_lane(), Lane::Normal);
    }

    #[test]
    fn tasks_inherit_the_submitters_lane() {
        // Every task of a high-lane job observes the high lane no matter
        // which thread claims it — so nested fan-outs submitted from
        // inside those tasks land in the high queue too.
        let exec = Executor::new(3);
        let items: Vec<usize> = (0..8).collect();
        let lanes = with_lane(Lane::High, || exec.map(&items, |_| current_lane()));
        assert!(lanes.iter().all(|&l| l == Lane::High), "{lanes:?}");
        assert_eq!(current_lane(), Lane::Normal);
        // Workers restore their own lane after helping a high job.
        let after = exec.map(&items, |_| current_lane());
        assert!(after.iter().all(|&l| l == Lane::Normal), "{after:?}");
    }

    #[test]
    fn idle_workers_drain_the_high_lane_first() {
        // caller thread + one background worker
        let exec = Executor::new(2);
        // (lane, ran on a pool worker thread) in task start order
        let order: Mutex<Vec<(Lane, bool)>> = Mutex::new(Vec::new());
        let started = AtomicUsize::new(0);
        std::thread::scope(|s| {
            // Plug the pool: a 2-task normal job parks its submitter and
            // the background worker until one high and one normal
            // contender are queued behind it (the plug itself stays in
            // the normal queue until a worker pops it, hence (1, 2)).
            s.spawn(|| {
                exec.map(&[0usize, 1], |_| {
                    started.fetch_add(1, Ordering::SeqCst);
                    while exec.queue_depths() != (1, 2) {
                        std::thread::yield_now();
                    }
                });
            });
            while started.load(Ordering::SeqCst) < 2 {
                std::thread::yield_now();
            }
            let record = |lane: Lane| {
                let worker = std::thread::current()
                    .name()
                    .is_some_and(|n| n.starts_with("cpsaa-exec"));
                order.lock().unwrap().push((lane, worker));
                // Long enough that the freed worker reaches the queue
                // while both contender jobs still have unclaimed tasks.
                std::thread::sleep(std::time::Duration::from_millis(50));
            };
            // Normal contender first, then the high one; the plug
            // releases only once both are enqueued.
            s.spawn(|| {
                exec.map(&[0usize, 1, 2], |_| record(Lane::Normal));
            });
            while exec.queue_depths() != (0, 2) {
                std::thread::yield_now();
            }
            s.spawn(|| {
                with_lane(Lane::High, || exec.map(&[0usize, 1, 2], |_| record(Lane::High)));
            });
        });
        // The freed background worker must have picked the high job even
        // though the normal contender was enqueued first.
        let order = order.into_inner().unwrap();
        let first_worker_task = order.iter().find(|(_, worker)| *worker);
        assert_eq!(
            first_worker_task,
            Some(&(Lane::High, true)),
            "worker drained the wrong lane first: {order:?}"
        );
    }

    #[test]
    fn idle_worker_with_empty_high_queue_steals_normal_work() {
        // With nothing in the high lane, background workers must pick up
        // normal jobs instead of sleeping until high work appears.
        let exec = Executor::new(4);
        let on_worker = AtomicUsize::new(0);
        let items: Vec<usize> = (0..32).collect();
        let out = exec.map(&items, |&x| {
            let worker = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("cpsaa-exec"));
            if worker {
                on_worker.fetch_add(1, Ordering::Relaxed);
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            x + 1
        });
        assert_eq!(out, (1..33).collect::<Vec<_>>());
        assert!(
            on_worker.load(Ordering::Relaxed) > 0,
            "no background worker stole from the normal lane"
        );
    }

    #[test]
    fn spawn_runs_detached_and_joins_with_the_value() {
        let exec = Executor::new(4);
        let handle = exec.spawn(|| (0..100u64).sum::<u64>());
        // The caller is free to do other work; the join returns the
        // task's value regardless of which thread ended up running it.
        assert_eq!(handle.join(), 4950);
    }

    #[test]
    fn spawn_on_a_serial_pool_runs_on_the_joiner() {
        let exec = Executor::new(1);
        let here = std::thread::current().id();
        let handle = exec.spawn(move || std::thread::current().id() == here);
        assert!(handle.join(), "workers=1 must degrade to run-on-join");
    }

    #[test]
    fn spawn_drop_without_join_still_completes_the_task() {
        let exec = Executor::new(2);
        let ran = Arc::new(AtomicUsize::new(0));
        let flag = ran.clone();
        drop(exec.spawn(move || {
            flag.fetch_add(1, Ordering::SeqCst);
        }));
        // Drop blocks until the task has run — never abandons it.
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[should_panic(expected = "executor worker")]
    fn spawn_join_re_raises_the_task_panic() {
        let exec = Executor::new(2);
        exec.spawn(|| panic!("boom")).join();
    }

    #[test]
    fn spawn_inherits_the_submitters_lane() {
        let exec = Executor::new(1);
        let lane = with_lane(Lane::High, || exec.spawn(current_lane)).join();
        assert_eq!(lane, Lane::High);
        assert_eq!(exec.spawn(current_lane).join(), Lane::Normal);
    }

    #[test]
    fn high_job_is_not_starved_behind_a_running_bulk_normal_job() {
        // Lane-starvation regression: the lane preference must hold at
        // task granularity, not just at job pick time. A worker already
        // grinding a bulk normal fan-out yields between task claims the
        // moment a high job is queued, helps it, then resumes the normal
        // job. Before the yield point existed, the high job here would
        // be run solely by its submitter: the bulk job (64×5 ms across
        // two threads ≈ 160 ms) outlives the submitter's solo pass over
        // the high job (8×5 ms = 40 ms), so no worker would ever touch
        // a high task.
        let exec = Executor::new(2); // caller + one background worker
        let normal_started = AtomicUsize::new(0);
        let high_on_worker = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                let items: Vec<usize> = (0..64).collect();
                let out = exec.map(&items, |&x| {
                    normal_started.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    x * 2
                });
                assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
            });
            // Both threads (submitter + worker) are inside the bulk job.
            while normal_started.load(Ordering::SeqCst) < 2 {
                std::thread::yield_now();
            }
            let items: Vec<usize> = (0..8).collect();
            let out = with_lane(Lane::High, || {
                exec.map(&items, |&x| {
                    let worker = std::thread::current()
                        .name()
                        .is_some_and(|n| n.starts_with("cpsaa-exec"));
                    if worker {
                        high_on_worker.fetch_add(1, Ordering::SeqCst);
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    x + 100
                })
            });
            assert_eq!(out, (100..108).collect::<Vec<_>>());
        });
        assert!(
            high_on_worker.load(Ordering::SeqCst) > 0,
            "background worker never yielded its normal job to the high lane"
        );
    }
}
