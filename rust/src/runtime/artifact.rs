//! Artifact discovery: manifest.json, weights.json, fixtures.json.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::attention::weights::json_matrix;
use crate::attention::MultiHeadWeights;
use crate::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::json::Json;

/// `artifacts/manifest.json` — shapes and files per compiled graph.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub artifacts: HashMap<String, ArtifactEntry>,
}

/// The ModelConfig the artifacts were lowered with (python defaults).
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub seq_len: usize,
    pub d_model: usize,
    pub d_k: usize,
    pub d_ff: usize,
    pub gamma: f32,
    pub quant_bits: u32,
    pub theta: f32,
    pub block: usize,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: String,
    /// Parameter shapes in call order.
    pub params: Vec<Vec<usize>>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let raw = Json::parse(text).context("parsing manifest.json")?;
        let c = raw.get("config")?;
        let config = ManifestConfig {
            seq_len: c.get("seq_len")?.as_usize()?,
            d_model: c.get("d_model")?.as_usize()?,
            d_k: c.get("d_k")?.as_usize()?,
            d_ff: c.get("d_ff")?.as_usize()?,
            gamma: c.get("gamma")?.as_f64()? as f32,
            quant_bits: c.get("quant_bits")?.as_usize()? as u32,
            theta: c.get("theta")?.as_f64()? as f32,
            block: c.get("block")?.as_usize()?,
            seed: c.get("seed")?.as_usize()? as u64,
        };
        let mut artifacts = HashMap::new();
        for (name, entry) in raw.get("artifacts")?.as_obj()? {
            let params = entry
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| p.as_arr()?.iter().map(Json::as_usize).collect())
                .collect::<Result<Vec<Vec<usize>>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactEntry { file: entry.get("file")?.as_str()?.to_string(), params },
            );
        }
        Ok(Self { config, artifacts })
    }
}

/// A located artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactSet {
    /// Load `dir/manifest.json` and validate the listed files exist.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts`)", manifest_path.display())
        })?;
        let manifest = Manifest::parse(&text)?;
        for (name, entry) in &manifest.artifacts {
            let p = dir.join(&entry.file);
            if !p.exists() {
                return Err(anyhow!("artifact {name} missing file {}", p.display()));
            }
        }
        Ok(Self { dir: dir.to_path_buf(), manifest })
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let entry =
            self.manifest.artifacts.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        Ok(self.dir.join(&entry.file))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn fixtures(&self) -> Result<Fixtures> {
        Fixtures::open(&self.dir.join("fixtures.json"))
    }

    /// Write a complete artifact directory for the native interpreter —
    /// manifest, synthetic weights (per-head when `model.heads > 1`),
    /// and HLO placeholder files — so the serving stack and its tests
    /// run without the python AOT step. The native engine executes from
    /// the manifest alone; the placeholders only satisfy the
    /// file-existence check that real AOT artifacts also pass.
    pub fn synthesize(dir: &Path, model: &ModelConfig, seed: u64) -> Result<ArtifactSet> {
        model.validate().map_err(|e| anyhow!(e))?;
        // Serving artifacts fan V across heads, so the serving-side
        // divisibility requirement applies here (the sim alone doesn't
        // need it, which is why ModelConfig::validate doesn't check).
        if model.d_model % model.heads.max(1) != 0 {
            return Err(anyhow!(
                "heads {} does not divide d_model {} (required to fan the serving weights)",
                model.heads,
                model.d_model
            ));
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let (n, d, dk, dff) = (model.seq_len, model.d_model, model.d_k, model.d_ff);
        let graphs: [(&str, String); 5] = [
            ("mask_gen", format!("[[{n}, {d}], [{d}, {d}]]")),
            ("attention", format!("[[{n}, {d}], [{d}, {d}], [{d}, {d}], [{n}, {n}]]")),
            ("sparse_attention", format!("[[{n}, {d}], [{d}, {d}], [{d}, {d}]]")),
            ("dense_attention", format!("[[{n}, {d}], [{d}, {d}], [{d}, {d}]]")),
            (
                "encoder",
                format!("[[{n}, {d}], [{d}, {d}], [{d}, {d}], [{d}, {dff}], [{dff}, {d}]]"),
            ),
        ];
        let mut manifest = String::from("{\n  \"config\": {");
        manifest.push_str(&format!(
            "\"seq_len\": {n}, \"d_model\": {d}, \"d_k\": {dk}, \"d_ff\": {dff}, \
             \"gamma\": {:?}, \"quant_bits\": {}, \"theta\": {:?}, \"block\": 32, \
             \"seed\": {seed}}},\n  \"artifacts\": {{\n",
            model.gamma, model.quant_bits, model.theta
        ));
        for (i, (name, params)) in graphs.iter().enumerate() {
            let file = format!("{name}.hlo.txt");
            std::fs::write(
                dir.join(&file),
                "; synthesized placeholder — the native interpreter executes from the manifest\n",
            )
            .with_context(|| format!("writing {file}"))?;
            manifest.push_str(&format!(
                "    \"{name}\": {{\"file\": \"{file}\", \"params\": {params}}}{}\n",
                if i + 1 < graphs.len() { "," } else { "" }
            ));
        }
        manifest.push_str("  }\n}\n");
        std::fs::write(dir.join("manifest.json"), manifest).context("writing manifest.json")?;
        let weights = MultiHeadWeights::synthetic(model, seed);
        std::fs::write(dir.join("weights.json"), weights.to_json_string())
            .context("writing weights.json")?;
        Self::open(dir)
    }
}

/// `artifacts/fixtures.json` — the python-side sample input and expected
/// outputs, used by integration tests to pin PJRT numerics to JAX.
#[derive(Clone, Debug)]
pub struct Fixtures {
    pub x: Matrix,
    /// Per-artifact expected output tuples.
    pub outputs: HashMap<String, Vec<Matrix>>,
}

impl Fixtures {
    pub fn open(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let raw = Json::parse(&text).context("parsing fixtures.json")?;
        let x = json_matrix(raw.get("x")?)?;
        let mut outputs = HashMap::new();
        for (name, arrays) in raw.get("outputs")?.as_obj()? {
            let mats: Result<Vec<Matrix>> = arrays.as_arr()?.iter().map(json_matrix).collect();
            outputs.insert(name.clone(), mats?);
        }
        Ok(Self { x, outputs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parse_minimal() {
        let text = r#"{
            "config": {"seq_len": 32, "d_model": 64, "d_k": 64, "d_ff": 128,
                       "gamma": 4.0, "quant_bits": 4, "theta": 0.01, "block": 32, "seed": 0},
            "artifacts": {"m": {"file": "m.hlo.txt", "params": [[32, 64]], "sha256_16": "x"}}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.config.seq_len, 32);
        assert_eq!(m.artifacts["m"].params, vec![vec![32, 64]]);
    }

    #[test]
    fn open_default_artifacts() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let set = ArtifactSet::open(&dir).unwrap();
        for name in ["mask_gen", "attention", "sparse_attention", "dense_attention", "encoder"] {
            assert!(set.manifest.artifacts.contains_key(name), "missing {name}");
            assert!(set.hlo_path(name).unwrap().exists());
        }
        assert_eq!(set.manifest.config.d_k, 64);
    }

    #[test]
    fn fixtures_consistent_with_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let set = ArtifactSet::open(&dir).unwrap();
        let fix = set.fixtures().unwrap();
        let cfg = &set.manifest.config;
        assert_eq!(fix.x.shape(), (cfg.seq_len, cfg.d_model));
        let z = &fix.outputs["sparse_attention"][0];
        assert_eq!(z.shape(), (cfg.seq_len, cfg.d_model));
        let mask = &fix.outputs["sparse_attention"][1];
        assert_eq!(mask.shape(), (cfg.seq_len, cfg.seq_len));
        // the fixture mask is binary
        assert!(mask.data().iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactSet::open(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn synthesize_roundtrips_through_open_and_engine() {
        use crate::attention::MultiHeadWeights;
        use crate::config::ModelConfig;
        let dir = std::env::temp_dir().join(format!("cpsaa-synth-art-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 5).unwrap();
        assert_eq!(set.manifest.config.seq_len, 16);
        assert_eq!(set.manifest.config.d_model, 32);
        assert_eq!(set.names().len(), 5);
        // the written weights load back with the synthesized head count
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        w.validate().unwrap();
        assert_eq!(w.heads(), 4);
        assert_eq!(w.heads[0].w_s, MultiHeadWeights::synthetic(&model, 5).heads[0].w_s);
        // and the native engine loads the set
        let engine = crate::runtime::Engine::load(&set).unwrap();
        assert_eq!(engine.model().seq_len, 16);
        std::fs::remove_dir_all(&dir).ok();
    }
}
