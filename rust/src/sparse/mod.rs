//! Sparse-matrix substrate: the pruning mask, its dispatch plan, and CSR
//! score matrices.
//!
//! The mask is the central object of CPSAA — it lives in the ReCAM
//! scheduler, drives the SDDMM dispatch (§4.3) and the SpMM V-row
//! replication (§4.4), and its density determines every speedup in the
//! evaluation. [`MaskMatrix`] stores it bit-packed per row; its one-time
//! ReCAM scan is materialized as a [`DispatchPlan`] (CSR topology,
//! per-column queue depths, 32×32 tile occupancy, per-row nnz) that every
//! kernel, simulator engine, and the coordinator consume instead of
//! re-walking the mask. [`CsrMatrix`] carries the sparse score values over
//! an owned copy of the plan's topology (reference paths); [`CsrView`]
//! borrows the topology from the plan and owns only its values — the
//! zero-copy format of the fused attention hot path. Multi-head batches
//! generalize the plan to a
//! [`PlanSet`] — one scan per head mask, heads scanned concurrently —
//! consumed the same way (per-head kernels, per-head tile-slice costing,
//! per-head serving metrics).

mod cache;
mod csr;
mod mask;
mod plan;
mod planset;
mod prune;

pub use cache::{PlanCache, PlanKey};
pub use csr::{CsrMatrix, CsrView};
pub(crate) use csr::{softmax_row, spmm_row_into};
pub use mask::{BlockCounts, MaskMatrix};
pub use plan::{DispatchPlan, DISPATCH_TILE};
pub use planset::{PlanSet, ShardedPlans};
pub use prune::{CascadeStats, LayerImportance, PruneConfig};
