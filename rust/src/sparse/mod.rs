//! Sparse-matrix substrate: the binary pruning mask and CSR score matrices.
//!
//! The mask is the central object of CPSAA — it lives in the ReCAM
//! scheduler, drives the SDDMM dispatch (§4.3) and the SpMM V-row
//! replication (§4.4), and its density determines every speedup in the
//! evaluation. [`MaskMatrix`] stores it bit-packed per row with the access
//! patterns the hardware needs: row-wise coordinate search (ReCAM
//! row-search → ⟨α, βᵢ⟩ streams) and per-tile population counts (the block
//! summary the Pallas kernels use).

mod csr;
mod mask;

pub use csr::CsrMatrix;
pub use mask::{BlockCounts, MaskMatrix};
