//! Cascade plan narrowing — score-driven top-k pruning between layers.
//!
//! SpAtten prunes tokens and heads *cumulatively* across encoder layers;
//! DSA derives the mask from runtime attention scores instead of a
//! static pattern. This module is that idea expressed on the
//! [`DispatchPlan`] substrate: each layer's fused pass retains its
//! plan-ordered softmax probabilities (values the kernel materializes
//! anyway — no extra pass over K), a [`LayerImportance`] reduces them
//! serially in plan order into per-token and per-head scores, and
//! [`PlanSet::narrow_cascade`] filters the existing u32 coordinate
//! stream with top-k keep sets ([`DispatchPlan::narrow`]) — the mask is
//! never rescanned, and deeper layers skip mask generation entirely.
//!
//! ## Determinism contract
//!
//! Narrowing decisions feed the serving determinism contract (replay
//! bit-compares pruned captures across worker/leader/shard topologies),
//! so every reduction here is order-fixed:
//!
//! * probability streams are retained in plan order — per-head fused
//!   tasks write disjoint row ranges of one buffer, so contents are
//!   identical at any worker count;
//! * shard slices are contiguous row ranges in order, so accumulating
//!   head-major across shards reproduces the unsharded (head, row)
//!   addition order exactly;
//! * top-k selection sorts by `(importance desc, index asc)` under
//!   `f64::total_cmp` — no partial-order ambiguity.
//!
//! `keep = 1.0` never reaches this module: the coordinator
//! short-circuits it to the literal static path, so exactness at
//! keep-ratio 1 is bit-identity by construction.

use super::plan::DispatchPlan;
use super::planset::PlanSet;

/// How the serving stack evolves each batch's [`PlanSet`] across
/// encoder layers.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum PruneConfig {
    /// Today's path: every layer generates its own masks and scans them.
    #[default]
    Static,
    /// Cascade narrowing: layer 0 scans, every deeper layer derives its
    /// plans by top-k filtering the previous layer's coordinate stream
    /// (cumulative). `keeps` is the per-step keep schedule: narrowing
    /// step `i` (into layer `i + 1`) keeps `keeps[i]` of the tokens and
    /// heads, clamping to the last entry past the end of the list — so
    /// `cascade:0.9,0.7,0.5` narrows layer 1 to 0.9, layer 2 to 0.7,
    /// and every deeper layer to 0.5, while the single-entry
    /// `cascade:K` keeps the historical uniform-ratio behavior.
    Cascade {
        /// Per-step keep fractions, each in `(0, 1]`, non-empty.
        keeps: Vec<f64>,
    },
}

impl PruneConfig {
    /// A uniform cascade: every narrowing step keeps the same fraction
    /// (the historical `cascade:K` config).
    pub fn cascade(keep: f64) -> Self {
        PruneConfig::Cascade { keeps: vec![keep] }
    }

    /// A per-layer cascade schedule (`cascade:K0,K1,...`).
    pub fn cascade_schedule(keeps: Vec<f64>) -> Self {
        PruneConfig::Cascade { keeps }
    }

    /// Whether this config actually changes execution. A cascade whose
    /// every step keeps 1.0 retains everything, so it short-circuits to
    /// the static path (the exactness-at-keep-ratio-1 contract:
    /// bit-identity by construction, at any topology).
    pub fn narrows(&self) -> bool {
        match self {
            PruneConfig::Static => false,
            PruneConfig::Cascade { keeps } => keeps.iter().any(|&k| k < 1.0),
        }
    }

    /// The keep-ratio of narrowing step `step` (the step deriving layer
    /// `step + 1`'s plans), clamping to the schedule's last entry.
    /// `None` for the static config.
    pub fn keep_at(&self, step: usize) -> Option<f64> {
        match self {
            PruneConfig::Static => None,
            PruneConfig::Cascade { keeps } => {
                Some(keeps[step.min(keeps.len().saturating_sub(1))])
            }
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let PruneConfig::Cascade { keeps } = self {
            if keeps.is_empty() {
                return Err("cascade keep schedule must not be empty".into());
            }
            for &keep in keeps {
                if !keep.is_finite() || keep <= 0.0 || keep > 1.0 {
                    return Err(format!("cascade keep-ratio must be in (0, 1], got {keep}"));
                }
            }
        }
        Ok(())
    }
}

impl std::fmt::Display for PruneConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PruneConfig::Static => write!(f, "static"),
            // Rust's shortest-round-trip float formatting: each entry
            // parses back to the identical bits, so the capture config
            // round-trips (single-entry schedules print as the
            // historical `cascade:K`).
            PruneConfig::Cascade { keeps } => {
                write!(f, "cascade:")?;
                for (i, k) in keeps.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::str::FromStr for PruneConfig {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let cfg = if s == "static" {
            PruneConfig::Static
        } else if let Some(r) = s.strip_prefix("cascade:") {
            let keeps: Vec<f64> = r
                .split(',')
                .map(|k| k.parse().map_err(|_| format!("bad cascade keep-ratio {k:?}")))
                .collect::<Result<_, _>>()?;
            PruneConfig::Cascade { keeps }
        } else {
            return Err(format!(
                "unknown prune mode {s:?} (expected static | cascade:<keep>[,<keep>...])"
            ));
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Per-token and per-head importance of one layer's attention pass,
/// reduced serially in plan order from the retained softmax
/// probabilities.
///
/// * `token[j]` — attention mass token `j` received *as a key* (its
///   column sum of the probability matrix), summed over heads. Tokens
///   nothing attends to are the cascade's pruning candidates.
/// * `head[h]` — head `h`'s focus: the sum over query rows of the row's
///   maximum probability. Diffuse heads (probability spread thin over
///   many keys) score low and are pruned first.
#[derive(Clone, Debug)]
pub struct LayerImportance {
    token: Vec<f64>,
    head: Vec<f64>,
}

impl LayerImportance {
    /// Start an empty accumulation over `tokens` key columns and
    /// `heads` heads.
    pub fn new(tokens: usize, heads: usize) -> Self {
        Self { token: vec![0.0; tokens], head: vec![0.0; heads] }
    }

    /// Fold one plan-ordered probability stream in: `probs[plan.row_range(i)]`
    /// holds query row `i`'s softmax row. Serial, plan order — calling
    /// this head-major across ordered contiguous shard slices reproduces
    /// the unsharded addition order bit for bit.
    pub fn add_rows(&mut self, head: usize, plan: &DispatchPlan, probs: &[f32]) {
        debug_assert_eq!(probs.len(), plan.nnz(), "probs must be the plan-ordered stream");
        for i in 0..plan.rows() {
            let range = plan.row_range(i);
            let mut row_max = 0.0f64;
            for (&j, &p) in plan.row_cols(i).iter().zip(&probs[range]) {
                let p = p as f64;
                self.token[j as usize] += p;
                if p > row_max {
                    row_max = p;
                }
            }
            self.head[head] += row_max;
        }
    }

    /// Per-token scores (column attention mass summed over heads).
    pub fn token_scores(&self) -> &[f64] {
        &self.token
    }

    /// Per-head focus scores.
    pub fn head_scores(&self) -> &[f64] {
        &self.head
    }

    /// Top-k keep sets at ratio `keep`: the `max(1, ceil(keep · n))`
    /// highest-scoring tokens and heads. Ties break by lower index;
    /// ordering is total (`f64::total_cmp`), so selection is
    /// deterministic at any topology.
    pub fn keep_masks(&self, keep: f64) -> (Vec<bool>, Vec<bool>) {
        (top_k_mask(&self.token, keep), top_k_mask(&self.head, keep))
    }
}

fn top_k_mask(scores: &[f64], keep: f64) -> Vec<bool> {
    let n = scores.len();
    if n == 0 {
        return Vec::new();
    }
    let k = ((keep * n as f64).ceil() as usize).clamp(1, n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let mut mask = vec![false; n];
    for &i in order.iter().take(k) {
        mask[i] = true;
    }
    mask
}

/// What one narrowing step kept (the per-layer plan stats surfaced in
/// `ServeMetrics` and response lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CascadeStats {
    /// Tokens kept (rows == cols of the square batch mask).
    pub rows_kept: usize,
    /// Heads kept.
    pub heads_kept: usize,
}

impl PlanSet {
    /// Derive the next layer's plan set by top-k narrowing: keep the
    /// `keep` fraction of tokens and heads ranked by `importance`, then
    /// filter every kept head's coordinate stream with
    /// [`DispatchPlan::narrow`] (pruned heads keep their shape but lose
    /// every coordinate). Cumulative by construction — narrowing the
    /// result narrows further, and the mask is never rescanned.
    pub fn narrow_cascade(
        &self,
        importance: &LayerImportance,
        keep: f64,
    ) -> (PlanSet, CascadeStats) {
        assert!(
            (0.0..=1.0).contains(&keep) && keep > 0.0,
            "keep-ratio must be in (0, 1], got {keep}"
        );
        let (keep_tok, keep_heads) = importance.keep_masks(keep);
        assert_eq!(keep_tok.len(), self.plan(0).cols(), "token scores match key columns");
        assert_eq!(keep_heads.len(), self.heads(), "head scores match heads");
        // Dropped query rows and dropped key columns are the same token
        // set: a pruned token neither issues nor receives attention.
        // (Plans are square in the serving path; guard non-square uses.)
        let keep_rows: Vec<bool> = if self.rows() == keep_tok.len() {
            keep_tok.clone()
        } else {
            vec![true; self.rows()]
        };
        let none_rows = vec![false; self.rows()];
        let plans: Vec<DispatchPlan> = self
            .plans()
            .iter()
            .zip(&keep_heads)
            .map(|(p, &kept)| {
                if kept {
                    p.narrow(&keep_rows, &keep_tok)
                } else {
                    p.narrow(&none_rows, &keep_tok)
                }
            })
            .collect();
        let stats = CascadeStats {
            rows_kept: keep_tok.iter().filter(|&&k| k).count(),
            heads_kept: keep_heads.iter().filter(|&&k| k).count(),
        };
        (PlanSet::from_plans(plans), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MaskMatrix;
    use crate::tensor::SeededRng;

    fn plan_set(heads: usize, n: usize, seed: u64) -> PlanSet {
        let mut rng = SeededRng::new(seed);
        let masks: Vec<MaskMatrix> = (0..heads)
            .map(|h| MaskMatrix::from_dense(&rng.mask_matrix(n, n, 0.2 + 0.1 * h as f64)))
            .collect();
        PlanSet::build(&masks)
    }

    /// Uniform probability streams for a plan set (each row sums to 1).
    fn uniform_probs(set: &PlanSet) -> Vec<Vec<f32>> {
        set.plans()
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.nnz()];
                for i in 0..p.rows() {
                    let r = p.row_range(i);
                    let nnz = r.len().max(1) as f32;
                    for x in &mut v[r] {
                        *x = 1.0 / nnz;
                    }
                }
                v
            })
            .collect()
    }

    #[test]
    fn prune_config_parses_and_round_trips() {
        assert_eq!("static".parse::<PruneConfig>().unwrap(), PruneConfig::Static);
        assert_eq!("cascade:0.5".parse::<PruneConfig>().unwrap(), PruneConfig::cascade(0.5));
        for cfg in [
            PruneConfig::Static,
            PruneConfig::cascade(0.625),
            PruneConfig::cascade_schedule(vec![0.9, 0.7, 0.5]),
        ] {
            assert_eq!(cfg.to_string().parse::<PruneConfig>().unwrap(), cfg);
        }
        assert!("cascade:0".parse::<PruneConfig>().is_err());
        assert!("cascade:1.5".parse::<PruneConfig>().is_err());
        assert!("cascade:nan".parse::<PruneConfig>().is_err());
        assert!("topk:0.5".parse::<PruneConfig>().is_err());
        assert!(!PruneConfig::Static.narrows());
        assert!(!PruneConfig::cascade(1.0).narrows());
        assert!(PruneConfig::cascade(0.5).narrows());
    }

    #[test]
    fn prune_schedule_parses_validates_and_clamps() {
        // a full schedule parses entry by entry...
        let cfg = "cascade:0.9,0.7,0.5".parse::<PruneConfig>().unwrap();
        assert_eq!(cfg, PruneConfig::cascade_schedule(vec![0.9, 0.7, 0.5]));
        // ...indexes per narrowing step, clamping to the last entry
        assert_eq!(cfg.keep_at(0), Some(0.9));
        assert_eq!(cfg.keep_at(1), Some(0.7));
        assert_eq!(cfg.keep_at(2), Some(0.5));
        assert_eq!(cfg.keep_at(7), Some(0.5));
        assert_eq!(PruneConfig::Static.keep_at(0), None);
        // narrows() looks at the whole schedule: all-ones is static
        assert!(!PruneConfig::cascade_schedule(vec![1.0, 1.0]).narrows());
        assert!(PruneConfig::cascade_schedule(vec![1.0, 0.5]).narrows());
        // any bad entry fails validation at parse time
        assert!("cascade:0.9,0".parse::<PruneConfig>().is_err());
        assert!("cascade:0.9,1.5".parse::<PruneConfig>().is_err());
        assert!("cascade:0.9,,0.5".parse::<PruneConfig>().is_err());
        assert!("cascade:".parse::<PruneConfig>().is_err());
        assert!(PruneConfig::cascade_schedule(vec![]).validate().is_err());
        assert!(PruneConfig::cascade_schedule(vec![0.9, f64::NAN]).validate().is_err());
    }

    #[test]
    fn top_k_mask_ranks_and_breaks_ties_by_index() {
        let scores = vec![0.3, 0.9, 0.3, 0.1];
        let mask = top_k_mask(&scores, 0.5);
        // k = 2: index 1 (0.9) then the tie at 0.3 goes to the lower
        // index 0, never index 2
        assert_eq!(mask, vec![true, true, false, false]);
        // keep everything
        assert_eq!(top_k_mask(&scores, 1.0), vec![true; 4]);
        // floor at one survivor
        assert_eq!(top_k_mask(&scores, 1e-9), vec![false, true, false, false]);
    }

    #[test]
    fn importance_accumulates_column_mass() {
        let set = plan_set(2, 16, 3);
        let probs = uniform_probs(&set);
        let mut imp = LayerImportance::new(16, 2);
        for (h, p) in probs.iter().enumerate() {
            imp.add_rows(h, set.plan(h), p);
        }
        // total token mass = one unit per nonempty row per head
        let nonempty: usize = set
            .plans()
            .iter()
            .map(|p| (0..p.rows()).filter(|&i| p.row_nnz(i) > 0).count())
            .sum();
        let total: f64 = imp.token_scores().iter().sum();
        assert!((total - nonempty as f64).abs() < 1e-6, "{total} vs {nonempty}");
        // head focus positive for nonempty plans
        assert!(imp.head_scores().iter().all(|&h| h > 0.0));
    }

    #[test]
    fn sharded_accumulation_matches_unsharded_bitwise() {
        let set = plan_set(3, 48, 5);
        let probs = uniform_probs(&set);
        let mut whole = LayerImportance::new(48, 3);
        for (h, p) in probs.iter().enumerate() {
            whole.add_rows(h, set.plan(h), p);
        }
        for shards in [2usize, 3, 5] {
            let sharded = set.shard(shards);
            let mut acc = LayerImportance::new(48, 3);
            // head-major over ordered shard slices = unsharded order
            for h in 0..3 {
                for s in 0..sharded.count() {
                    let sub = sharded.set(s).plan(h);
                    let r = sharded.range(s);
                    let full = set.plan(h);
                    let lo = full.row_range(r.start).start;
                    let hi = full.row_range(r.end - 1).end;
                    acc.add_rows(h, sub, &probs[h][lo..hi]);
                }
            }
            for (a, b) in whole.token_scores().iter().zip(acc.token_scores()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{shards} shards");
            }
            for (a, b) in whole.head_scores().iter().zip(acc.head_scores()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{shards} shards");
            }
        }
    }

    #[test]
    fn narrow_cascade_prunes_tokens_and_heads() {
        let set = plan_set(4, 32, 7);
        let probs = uniform_probs(&set);
        let mut imp = LayerImportance::new(32, 4);
        for (h, p) in probs.iter().enumerate() {
            imp.add_rows(h, set.plan(h), p);
        }
        let (narrowed, stats) = set.narrow_cascade(&imp, 0.5);
        assert_eq!(narrowed.heads(), 4);
        assert_eq!(stats.rows_kept, 16);
        assert_eq!(stats.heads_kept, 2);
        assert!(narrowed.total_nnz() < set.total_nnz());
        // pruned heads lost every coordinate but kept their shape
        let (_, keep_heads) = imp.keep_masks(0.5);
        for (h, &kept) in keep_heads.iter().enumerate() {
            assert_eq!(narrowed.plan(h).rows(), 32, "head {h}");
            if !kept {
                assert_eq!(narrowed.plan(h).nnz(), 0, "pruned head {h} must be empty");
            }
        }
        // cumulative: narrowing again with the same scores is a fixpoint
        // on the keep sets (the kept coordinates survive)
        let (again, stats2) = narrowed.narrow_cascade(&imp, 1.0);
        assert_eq!(again, narrowed);
        assert_eq!(stats2.rows_kept, 32);
    }
}
