//! `PlanSet` — one [`DispatchPlan`] per attention head.
//!
//! CPSAA runs attention heads concurrently on disjoint crossbar-tile
//! slices (§4.5): each head owns `tiles/heads` of the chip, and each
//! head's pruning mask drives its *own* ReCAM scheduler. The plan set is
//! the multi-head generalization of the single plan: one scan per head
//! mask, performed once per packed batch, shared by the attention
//! kernels (per-head SDDMM/SpMM dispatch), the simulator (per-head cost
//! attribution on a tile slice), and the coordinator (per-head metrics).
//!
//! Like the single-plan path, no consumer re-walks a mask: everything
//! downstream reads the per-head plans built here.
//!
//! Batch-parallel sharding generalizes the set once more: a
//! [`ShardedPlans`] partitions the batch rows into nnz-balanced
//! contiguous ranges ([`PlanSet::partition_rows`], weights summed over
//! heads) and slices every head's plan to each range
//! ([`PlanSet::slice_rows`]) — one plan set per shard, no rescan, each
//! shard a logical chip.

use crate::runtime::executor::{self, Executor};

use super::mask::MaskMatrix;
use super::plan::DispatchPlan;

/// Per-head dispatch plans of one packed batch (index = head).
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSet {
    plans: Vec<DispatchPlan>,
}

impl PlanSet {
    /// One ReCAM scan per head mask on the global executor pool. Head
    /// scans are independent (each head's ReCAM slice searches its own
    /// mask), so large masks scan concurrently.
    pub fn build(masks: &[MaskMatrix]) -> Self {
        Self::build_in(&executor::global(), masks)
    }

    /// [`PlanSet::build`] on a caller-owned [`Executor`] — the engine's
    /// injectable dispatch path. Small masks fall below the executor's
    /// grain and scan serially on the caller (the shared serial-fallback
    /// heuristic; there is no per-site threshold anymore).
    pub fn build_in(exec: &Executor, masks: &[MaskMatrix]) -> Self {
        assert!(!masks.is_empty(), "PlanSet needs at least one head mask");
        let shape = (masks[0].rows(), masks[0].cols());
        for m in masks {
            assert_eq!((m.rows(), m.cols()), shape, "head masks must share one shape");
        }
        // Identical head masks (the replicated single-head fan-out) need
        // one scan, not `heads` — the bit-packed equality probe is
        // O(cells/64) against O(nnz) scans.
        if masks.len() > 1 && masks.iter().skip(1).all(|m| m == &masks[0]) {
            return Self { plans: vec![masks[0].plan(); masks.len()] };
        }
        let plans = if exec.workers_for(shape.0 * shape.1) > 1 {
            exec.map(masks, |m| m.plan())
        } else {
            masks.iter().map(|m| m.plan()).collect()
        };
        Self { plans }
    }

    /// Adopt prebuilt plans (e.g. one plan replicated across heads that
    /// share a mask — the application-level sim's shortcut).
    pub fn from_plans(plans: Vec<DispatchPlan>) -> Self {
        assert!(!plans.is_empty(), "PlanSet needs at least one plan");
        Self { plans }
    }

    /// A single-head set.
    pub fn single(plan: DispatchPlan) -> Self {
        Self { plans: vec![plan] }
    }

    /// Number of heads.
    pub fn heads(&self) -> usize {
        self.plans.len()
    }

    /// Head `h`'s plan.
    pub fn plan(&self, h: usize) -> &DispatchPlan {
        &self.plans[h]
    }

    /// All plans, head order.
    pub fn plans(&self) -> &[DispatchPlan] {
        &self.plans
    }

    /// Masked coordinates summed over heads.
    pub fn total_nnz(&self) -> usize {
        self.plans.iter().map(DispatchPlan::nnz).sum()
    }

    /// Per-head densities, head order.
    pub fn densities(&self) -> Vec<f64> {
        self.plans.iter().map(DispatchPlan::density).collect()
    }

    /// Mean density across heads.
    pub fn mean_density(&self) -> f64 {
        self.densities().iter().sum::<f64>() / self.plans.len() as f64
    }

    /// Deepest single-column queue over any head — the serialization
    /// bound of the slowest head's SDDMM dispatch.
    pub fn max_col_queue(&self) -> u64 {
        self.plans.iter().map(DispatchPlan::max_col_queue).max().unwrap_or(0)
    }

    /// Batch rows (head masks share one shape).
    pub fn rows(&self) -> usize {
        self.plans[0].rows()
    }

    /// Split `0..rows` into at most `parts` contiguous ranges balanced
    /// by the per-row nnz *summed over heads* — the batch-parallel
    /// shard partition. Every shard runs all heads on its row slice, so
    /// its work is the row's total coordinate count across heads, not
    /// the row count.
    pub fn partition_rows(&self, parts: usize) -> Vec<std::ops::Range<usize>> {
        super::plan::partition_by_weights(
            self.rows(),
            |i| self.plans.iter().map(|p| p.row_nnz(i)).sum(),
            parts,
        )
    }

    /// Every head's plan sliced to the contiguous row range — one
    /// shard's plan set (no rescan; see [`DispatchPlan::slice_rows`]).
    pub fn slice_rows(&self, rows: std::ops::Range<usize>) -> PlanSet {
        Self { plans: self.plans.iter().map(|p| p.slice_rows(rows.clone())).collect() }
    }

    /// Partition the batch into `shards` nnz-balanced row ranges and
    /// slice every head's plan to each — the per-shard view consumed by
    /// the sharded kernels, the multi-chip simulator, and the
    /// coordinator's shard accounting.
    pub fn shard(&self, shards: usize) -> ShardedPlans {
        let ranges = self.partition_rows(shards);
        let sets = ranges.iter().map(|r| self.slice_rows(r.clone())).collect();
        ShardedPlans { ranges, sets }
    }
}

/// The per-shard view of one batch's [`PlanSet`]: contiguous row ranges
/// exactly tiling `0..rows` (at most the requested shard count, never
/// empty) and each head's plans sliced to them. Shard `s` is one
/// logical chip: it executes and is costed over `sets[s]` while reading
/// the full batch for keys/values.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardedPlans {
    ranges: Vec<std::ops::Range<usize>>,
    sets: Vec<PlanSet>,
}

impl ShardedPlans {
    /// Number of shards actually cut (≤ requested; small or empty
    /// batches may not fill every chip).
    pub fn count(&self) -> usize {
        self.ranges.len()
    }

    /// Shard `s`'s batch-row range.
    pub fn range(&self, s: usize) -> &std::ops::Range<usize> {
        &self.ranges[s]
    }

    /// All shard ranges, shard order.
    pub fn ranges(&self) -> &[std::ops::Range<usize>] {
        &self.ranges
    }

    /// Shard `s`'s sliced plan set (one plan per head).
    pub fn set(&self, s: usize) -> &PlanSet {
        &self.sets[s]
    }

    /// All shard plan sets, shard order.
    pub fn sets(&self) -> &[PlanSet] {
        &self.sets
    }

    /// Per-shard coordinate load (nnz summed over heads), shard order —
    /// the balance the partition optimizes.
    pub fn shard_nnz(&self) -> Vec<usize> {
        self.sets.iter().map(PlanSet::total_nnz).collect()
    }

    /// Per-shard row counts, shard order.
    pub fn shard_rows(&self) -> Vec<usize> {
        self.ranges.iter().map(|r| r.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn masks(heads: usize, n: usize, seed: u64) -> Vec<MaskMatrix> {
        let mut rng = SeededRng::new(seed);
        (0..heads)
            .map(|h| {
                let density = 0.05 + 0.1 * h as f64;
                MaskMatrix::from_dense(&rng.mask_matrix(n, n, density))
            })
            .collect()
    }

    #[test]
    fn build_matches_per_mask_plans() {
        let ms = masks(4, 96, 1);
        let set = PlanSet::build(&ms);
        assert_eq!(set.heads(), 4);
        for (h, m) in ms.iter().enumerate() {
            assert_eq!(set.plan(h), &m.plan(), "head {h} diverged");
        }
        assert_eq!(set.total_nnz(), ms.iter().map(MaskMatrix::nnz).sum::<usize>());
    }

    #[test]
    fn densities_in_head_order() {
        let ms = masks(3, 64, 2);
        let set = PlanSet::build(&ms);
        let d = set.densities();
        assert_eq!(d.len(), 3);
        for (h, m) in ms.iter().enumerate() {
            assert!((d[h] - m.density()).abs() < 1e-12, "head {h}");
        }
        let mean = set.mean_density();
        assert!((mean - d.iter().sum::<f64>() / 3.0).abs() < 1e-12);
    }

    #[test]
    fn single_and_from_plans() {
        let m = masks(1, 32, 3).remove(0);
        let set = PlanSet::single(m.plan());
        assert_eq!(set.heads(), 1);
        assert_eq!(set.plan(0).nnz(), m.nnz());
        let rep = PlanSet::from_plans(vec![m.plan(); 8]);
        assert_eq!(rep.heads(), 8);
        assert_eq!(rep.total_nnz(), 8 * m.nnz());
        assert_eq!(rep.max_col_queue(), m.plan().max_col_queue());
    }

    #[test]
    #[should_panic(expected = "share one shape")]
    fn shape_mismatch_rejected() {
        let a = MaskMatrix::zeros(8, 8);
        let b = MaskMatrix::zeros(8, 9);
        PlanSet::build(&[a, b]);
    }

    #[test]
    fn identical_masks_share_one_scan() {
        let m = masks(1, 64, 5).remove(0);
        let set = PlanSet::build(&vec![m.clone(); 4]);
        assert_eq!(set.heads(), 4);
        let want = m.plan();
        for h in 0..4 {
            assert_eq!(set.plan(h), &want, "head {h}");
        }
    }

    #[test]
    fn shard_ranges_tile_rows_and_slice_per_head() {
        let ms = masks(3, 96, 7);
        let set = PlanSet::build(&ms);
        let sharded = set.shard(4);
        assert!(sharded.count() >= 1 && sharded.count() <= 4);
        let mut cursor = 0usize;
        for s in 0..sharded.count() {
            let r = sharded.range(s);
            assert_eq!(r.start, cursor, "shard {s} not contiguous");
            assert!(r.end > r.start, "shard {s} empty");
            cursor = r.end;
            let sub = sharded.set(s);
            assert_eq!(sub.heads(), 3);
            for h in 0..3 {
                assert_eq!(sub.plan(h), &set.plan(h).slice_rows(r.clone()), "shard {s} head {h}");
            }
        }
        assert_eq!(cursor, 96, "shards must tile 0..rows");
        assert_eq!(sharded.shard_nnz().iter().sum::<usize>(), set.total_nnz());
        assert_eq!(sharded.shard_rows().iter().sum::<usize>(), 96);
    }

    #[test]
    fn partition_weights_sum_over_heads() {
        // Two heads with different densities: the partition must
        // balance the *combined* per-row load, and conserve total nnz.
        let ms = masks(2, 64, 9);
        let set = PlanSet::build(&ms);
        let ranges = set.partition_rows(2);
        let load = |r: &std::ops::Range<usize>| -> usize {
            r.clone().map(|i| set.plan(0).row_nnz(i) + set.plan(1).row_nnz(i)).sum()
        };
        let loads: Vec<usize> = ranges.iter().map(load).collect();
        assert_eq!(loads.iter().sum::<usize>(), set.total_nnz());
        if loads.len() == 2 {
            let (max, min) = (*loads.iter().max().unwrap(), *loads.iter().min().unwrap());
            assert!(max <= 2 * min.max(1) + 64, "imbalanced: {loads:?}");
        }
    }

    #[test]
    fn one_shard_is_the_whole_batch() {
        let ms = masks(2, 48, 10);
        let set = PlanSet::build(&ms);
        let sharded = set.shard(1);
        assert_eq!(sharded.count(), 1);
        assert_eq!(sharded.range(0), &(0..48));
        assert_eq!(sharded.set(0), &set, "full-range slice must reproduce the set");
    }

    #[test]
    fn small_masks_scan_serially_same_result() {
        // Below the parallel threshold the serial path must agree.
        let ms = masks(2, 16, 4);
        let set = PlanSet::build(&ms);
        assert_eq!(set.plan(0), &ms[0].plan());
        assert_eq!(set.plan(1), &ms[1].plan());
    }
}
