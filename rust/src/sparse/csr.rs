//! CSR sparse matrix — the runtime format of the sparse score matrix S.
//!
//! The paper's comparison platforms (GPU cuSPARSE discussion in §5, SANGER's
//! split-and-pack) all reason about compressed formats; the baselines model
//! their conversion overhead, and the golden model uses CSR for the sparse
//! softmax/SpMM reference path.
//!
//! Two flavors:
//!
//! * [`CsrMatrix`] — owns its topology (a `u32` copy of the plan's
//!   stream). The reference/compat format: round-trips to dense, feeds
//!   the unfused golden chain and the conversion-cost baselines.
//! * [`CsrView`] — borrows the topology straight from a
//!   [`DispatchPlan`] and owns only the values. The hot-path format: one
//!   value buffer (workspace-recycled) per kernel call, zero topology
//!   copies, exactly like the crossbar engines that read the ReCAM
//!   coordinate stream in place.

use crate::sparse::{DispatchPlan, MaskMatrix};
use crate::tensor::{simd, Matrix};

/// Row-wise streaming softmax over one row's stored entries (laned
/// max-reduce → elementwise exp → laned sum-reduce → normalize) — shared
/// by [`CsrMatrix`], [`CsrView`] and the fused kernel so every path
/// computes bit-identical probabilities. The reductions go through
/// `tensor::simd`, whose scalar fallback replays the identical lane
/// order, so the probabilities are also mode-invariant.
pub(crate) fn softmax_row(vals: &mut [f32]) {
    if vals.is_empty() {
        return;
    }
    let max = simd::max_reduce(vals);
    for v in vals.iter_mut() {
        *v = (*v - max).exp();
    }
    let sum = simd::sum(vals);
    for v in vals.iter_mut() {
        *v /= sum;
    }
}

/// One sparse row times a dense matrix, accumulated into a zero-initialized
/// output row — the SpMM inner loop every CSR flavor and the fused kernel
/// share (same accumulation order ⇒ same bits). Each selected V row lands
/// via the laned axpy primitive.
pub(crate) fn spmm_row_into(
    cols: &[u32],
    vals: &[f32],
    dense: &Matrix,
    out_row: &mut [f32],
) {
    for (&j, &v) in cols.iter().zip(vals) {
        simd::axpy(v, dense.row(j as usize), out_row);
    }
}

/// Compressed sparse row f32 matrix (owned topology, `u32` indices).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Adopt the plan's topology, gathering values from a dense matrix.
    pub fn from_plan(plan: &DispatchPlan, m: &Matrix) -> Self {
        assert_eq!((m.rows(), m.cols()), (plan.rows(), plan.cols()));
        let mut values = Vec::with_capacity(plan.nnz());
        for i in 0..plan.rows() {
            for &j in plan.row_cols(i) {
                values.push(m.get(i, j as usize));
            }
        }
        Self::from_plan_values(plan, values)
    }

    /// Adopt the plan's topology with values supplied directly in plan
    /// order. This *copies* the topology (owned format); the hot kernels
    /// use [`CsrView::new`] instead, which borrows it.
    pub fn from_plan_values(plan: &DispatchPlan, values: Vec<f32>) -> Self {
        assert_eq!(values.len(), plan.nnz(), "values do not match plan topology");
        Self {
            rows: plan.rows(),
            cols: plan.cols(),
            row_ptr: plan.row_ptr().to_vec(),
            col_idx: plan.col_idx().to_vec(),
            values,
        }
    }

    /// Compress a dense matrix, keeping entries where `mask` is set.
    /// (Convenience over [`CsrMatrix::from_plan`] — builds a throwaway
    /// plan; callers on the hot path should build the plan once and
    /// reuse it.)
    pub fn from_dense_masked(m: &Matrix, mask: &MaskMatrix) -> Self {
        Self::from_plan(&mask.plan(), m)
    }

    /// Compress keeping all non-zero entries.
    pub fn from_dense(m: &Matrix) -> Self {
        Self::from_dense_masked(m, &MaskMatrix::from_dense(m))
    }

    /// Scale every stored value (the 1/√d_k factor of the score matrix).
    pub fn scale_values(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row `i`'s span of the flat value/coordinate stream.
    fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize
    }

    /// (column, value) pairs of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let r = self.row_range(i);
        self.col_idx[r.clone()]
            .iter()
            .map(|&j| j as usize)
            .zip(self.values[r].iter().copied())
    }

    /// Row-wise softmax over the stored entries only — the SU applied to a
    /// sparse S (masked-out entries carry no probability mass).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let r = self.row_range(i);
            softmax_row(&mut self.values[r]);
        }
    }

    /// SpMM: `self @ dense` — the golden reference for the crossbar SpMM
    /// engine (§4.4). Accumulates straight into the zero-initialized
    /// output row (no per-row scratch allocation).
    pub fn spmm(&self, dense: &Matrix) -> Matrix {
        assert_eq!(self.cols, dense.rows());
        let m = dense.cols();
        let mut out = Matrix::zeros(self.rows, m);
        for i in 0..self.rows {
            let r = self.row_range(i);
            spmm_row_into(
                &self.col_idx[r.clone()],
                &self.values[r],
                dense,
                out.row_mut(i),
            );
        }
        out
    }

    /// Back to dense (tests).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row(i) {
                out.set(i, j, v);
            }
        }
        out
    }

    /// Density of stored entries.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }
}

/// Zero-copy CSR over a [`DispatchPlan`]'s topology.
///
/// Ownership contract: the *plan* owns `row_ptr`/`col_idx` (built once
/// per mask, shared by every kernel, layer, head and shard); the view
/// owns only its value buffer. Kernels build one view per call from a
/// workspace-recycled `Vec<f32>` and hand the buffer back with
/// [`CsrView::into_values`] when done — nothing about the topology is
/// ever cloned on the hot path.
#[derive(Debug)]
pub struct CsrView<'p> {
    plan: &'p DispatchPlan,
    values: Vec<f32>,
}

impl<'p> CsrView<'p> {
    /// Wrap plan-ordered values (len == `plan.nnz()`) over the plan's
    /// borrowed topology.
    pub fn new(plan: &'p DispatchPlan, values: Vec<f32>) -> Self {
        assert_eq!(values.len(), plan.nnz(), "values do not match plan topology");
        Self { plan, values }
    }

    /// The topology this view borrows.
    pub fn plan(&self) -> &'p DispatchPlan {
        self.plan
    }

    pub fn rows(&self) -> usize {
        self.plan.rows()
    }

    pub fn cols(&self) -> usize {
        self.plan.cols()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Plan-ordered values.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Reclaim the value buffer (workspace recycling).
    pub fn into_values(self) -> Vec<f32> {
        self.values
    }

    /// (column, value) pairs of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let r = self.plan.row_range(i);
        self.plan.row_cols(i).iter().map(|&j| j as usize).zip(self.values[r].iter().copied())
    }

    /// Scale every stored value (the 1/√d_k factor).
    pub fn scale_values(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    /// Row-wise softmax over stored entries — bit-identical to
    /// [`CsrMatrix::softmax_rows`] (same shared row kernel).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows() {
            let r = self.plan.row_range(i);
            softmax_row(&mut self.values[r]);
        }
    }

    /// SpMM into a caller-owned output buffer (reshaped and zeroed in
    /// place) — the workspace path. Bit-identical to
    /// [`CsrMatrix::spmm`] (same shared row kernel).
    pub fn spmm_into(&self, dense: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols(), dense.rows());
        out.reset(self.rows(), dense.cols());
        for i in 0..self.rows() {
            let r = self.plan.row_range(i);
            spmm_row_into(self.plan.row_cols(i), &self.values[r], dense, out.row_mut(i));
        }
    }

    /// SpMM: `self @ dense`, allocating the output.
    pub fn spmm(&self, dense: &Matrix) -> Matrix {
        let mut out = Matrix::default();
        self.spmm_into(dense, &mut out);
        out
    }

    /// Owned copy (tests / conversion-cost baselines).
    pub fn to_owned_csr(&self) -> CsrMatrix {
        CsrMatrix::from_plan_values(self.plan, self.values.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn sample(seed: u64, n: usize, m: usize, density: f64) -> (Matrix, MaskMatrix) {
        let mut rng = SeededRng::new(seed);
        let dense = rng.normal_matrix(n, m, 1.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(n, m, density));
        (dense, mask)
    }

    #[test]
    fn roundtrip_masked() {
        let (dense, mask) = sample(1, 16, 24, 0.3);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let back = csr.to_dense();
        for i in 0..16 {
            for j in 0..24 {
                let want = if mask.get(i, j) { dense.get(i, j) } else { 0.0 };
                assert_eq!(back.get(i, j), want);
            }
        }
    }

    #[test]
    fn nnz_matches_mask() {
        let (dense, mask) = sample(2, 32, 32, 0.1);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        assert_eq!(csr.nnz(), mask.nnz());
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let (dense, mask) = sample(3, 16, 16, 0.4);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let v = SeededRng::new(4).normal_matrix(16, 8, 1.0);
        let got = csr.spmm(&v);
        let want = csr.to_dense().matmul(&v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn softmax_rows_normalize() {
        let (dense, mask) = sample(5, 12, 12, 0.5);
        let mut csr = CsrMatrix::from_dense_masked(&dense, &mask);
        csr.softmax_rows();
        for i in 0..12 {
            let s: f32 = csr.row(i).map(|(_, v)| v).sum();
            if mask.row_nnz(i) > 0 {
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            } else {
                assert_eq!(s, 0.0);
            }
        }
    }

    #[test]
    fn softmax_empty_rows_ok() {
        let mut csr = CsrMatrix::from_dense(&Matrix::zeros(4, 4));
        csr.softmax_rows(); // no panic, nothing stored
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn from_plan_matches_from_dense_masked() {
        let (dense, mask) = sample(7, 24, 40, 0.25);
        let plan = mask.plan();
        let a = CsrMatrix::from_plan(&plan, &dense);
        let b = CsrMatrix::from_dense_masked(&dense, &mask);
        assert_eq!(a, b);
        let vals: Vec<f32> = (0..plan.nnz()).map(|k| k as f32).collect();
        let c = CsrMatrix::from_plan_values(&plan, vals.clone());
        assert_eq!(c.nnz(), plan.nnz());
        let collected: Vec<f32> = (0..24).flat_map(|i| c.row(i).map(|(_, v)| v)).collect();
        assert_eq!(collected, vals);
    }

    #[test]
    fn scale_values_scales() {
        let (dense, mask) = sample(8, 8, 8, 0.5);
        let mut csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let before: Vec<f32> = csr.row(0).map(|(_, v)| v).collect();
        csr.scale_values(2.0);
        let after: Vec<f32> = csr.row(0).map(|(_, v)| v).collect();
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(*a, 2.0 * *b);
        }
    }

    #[test]
    fn spmm_identity() {
        let (dense, _) = sample(6, 8, 8, 1.0);
        let csr = CsrMatrix::from_dense(&dense);
        let got = csr.spmm(&Matrix::eye(8));
        assert!(got.max_abs_diff(&dense) < 1e-6);
    }

    #[test]
    fn view_matches_owned_csr_bitwise() {
        let (dense, mask) = sample(9, 24, 32, 0.3);
        let plan = mask.plan();
        let mut owned = CsrMatrix::from_plan(&plan, &dense);
        let vals: Vec<f32> = (0..plan.rows()).flat_map(|i| owned.row(i).map(|(_, v)| v)).collect();
        let mut view = CsrView::new(&plan, vals);
        assert_eq!((view.rows(), view.cols(), view.nnz()), (24, 32, plan.nnz()));
        owned.scale_values(0.5);
        view.scale_values(0.5);
        owned.softmax_rows();
        view.softmax_rows();
        assert_eq!(view.to_owned_csr(), owned, "view ops diverged from owned CSR");
        let v = SeededRng::new(10).normal_matrix(32, 8, 1.0);
        let want = owned.spmm(&v);
        assert_eq!(view.spmm(&v), want, "spmm bits diverged");
        // spmm_into must fully overwrite a stale, larger buffer
        let mut out = Matrix::full(40, 40, 7.0);
        view.spmm_into(&v, &mut out);
        assert_eq!(out, want);
        // buffer recycling round-trip
        let n = view.nnz();
        let buf = view.into_values();
        assert_eq!(buf.len(), n);
    }

    #[test]
    fn view_empty_rows_ok() {
        let plan = MaskMatrix::zeros(4, 4).plan();
        let mut view = CsrView::new(&plan, Vec::new());
        view.softmax_rows();
        let z = view.spmm(&Matrix::eye(4));
        assert_eq!(z.norm(), 0.0);
        assert_eq!(z.shape(), (4, 4));
    }
}
