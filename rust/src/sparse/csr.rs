//! CSR sparse matrix — the runtime format of the sparse score matrix S.
//!
//! The paper's comparison platforms (GPU cuSPARSE discussion in §5, SANGER's
//! split-and-pack) all reason about compressed formats; the baselines model
//! their conversion overhead, and the golden model uses CSR for the sparse
//! softmax/SpMM reference path.

use crate::sparse::{DispatchPlan, MaskMatrix};
use crate::tensor::Matrix;

/// Compressed sparse row f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Adopt the plan's topology, gathering values from a dense matrix.
    pub fn from_plan(plan: &DispatchPlan, m: &Matrix) -> Self {
        assert_eq!((m.rows(), m.cols()), (plan.rows(), plan.cols()));
        let mut values = Vec::with_capacity(plan.nnz());
        for i in 0..plan.rows() {
            for &j in plan.row_cols(i) {
                values.push(m.get(i, j));
            }
        }
        Self::from_plan_values(plan, values)
    }

    /// Adopt the plan's topology with values supplied directly in plan
    /// order (the SDDMM kernels write straight into this — no dense S
    /// round-trip).
    pub fn from_plan_values(plan: &DispatchPlan, values: Vec<f32>) -> Self {
        assert_eq!(values.len(), plan.nnz(), "values do not match plan topology");
        Self {
            rows: plan.rows(),
            cols: plan.cols(),
            row_ptr: plan.row_ptr().to_vec(),
            col_idx: plan.col_idx().to_vec(),
            values,
        }
    }

    /// Compress a dense matrix, keeping entries where `mask` is set.
    /// (Convenience over [`CsrMatrix::from_plan`] — builds a throwaway
    /// plan; callers on the hot path should build the plan once and
    /// reuse it.)
    pub fn from_dense_masked(m: &Matrix, mask: &MaskMatrix) -> Self {
        Self::from_plan(&mask.plan(), m)
    }

    /// Compress keeping all non-zero entries.
    pub fn from_dense(m: &Matrix) -> Self {
        Self::from_dense_masked(m, &MaskMatrix::from_dense(m))
    }

    /// Scale every stored value (the 1/√d_k factor of the score matrix).
    pub fn scale_values(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (column, value) pairs of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_idx[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Mutable values of row `i` (used by the sparse softmax).
    fn row_values_mut(&mut self, i: usize) -> &mut [f32] {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        &mut self.values[lo..hi]
    }

    /// Row-wise softmax over the stored entries only — the SU applied to a
    /// sparse S (masked-out entries carry no probability mass).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let vals = self.row_values_mut(i);
            if vals.is_empty() {
                continue;
            }
            let max = vals.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in vals.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in vals.iter_mut() {
                *v /= sum;
            }
        }
    }

    /// SpMM: `self @ dense` — the golden reference for the crossbar SpMM
    /// engine (§4.4).
    pub fn spmm(&self, dense: &Matrix) -> Matrix {
        assert_eq!(self.cols, dense.rows());
        let m = dense.cols();
        let mut out = Matrix::zeros(self.rows, m);
        for i in 0..self.rows {
            // split borrows: write into a scratch row then copy
            let mut acc = vec![0.0f32; m];
            for (j, v) in self.row(i) {
                let drow = dense.row(j);
                for (a, d) in acc.iter_mut().zip(drow) {
                    *a += v * d;
                }
            }
            out.data_mut()[i * m..(i + 1) * m].copy_from_slice(&acc);
        }
        out
    }

    /// Back to dense (tests).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row(i) {
                out.set(i, j, v);
            }
        }
        out
    }

    /// Density of stored entries.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn sample(seed: u64, n: usize, m: usize, density: f64) -> (Matrix, MaskMatrix) {
        let mut rng = SeededRng::new(seed);
        let dense = rng.normal_matrix(n, m, 1.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(n, m, density));
        (dense, mask)
    }

    #[test]
    fn roundtrip_masked() {
        let (dense, mask) = sample(1, 16, 24, 0.3);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let back = csr.to_dense();
        for i in 0..16 {
            for j in 0..24 {
                let want = if mask.get(i, j) { dense.get(i, j) } else { 0.0 };
                assert_eq!(back.get(i, j), want);
            }
        }
    }

    #[test]
    fn nnz_matches_mask() {
        let (dense, mask) = sample(2, 32, 32, 0.1);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        assert_eq!(csr.nnz(), mask.nnz());
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let (dense, mask) = sample(3, 16, 16, 0.4);
        let csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let v = SeededRng::new(4).normal_matrix(16, 8, 1.0);
        let got = csr.spmm(&v);
        let want = csr.to_dense().matmul(&v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn softmax_rows_normalize() {
        let (dense, mask) = sample(5, 12, 12, 0.5);
        let mut csr = CsrMatrix::from_dense_masked(&dense, &mask);
        csr.softmax_rows();
        for i in 0..12 {
            let s: f32 = csr.row(i).map(|(_, v)| v).sum();
            if mask.row_nnz(i) > 0 {
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            } else {
                assert_eq!(s, 0.0);
            }
        }
    }

    #[test]
    fn softmax_empty_rows_ok() {
        let mut csr = CsrMatrix::from_dense(&Matrix::zeros(4, 4));
        csr.softmax_rows(); // no panic, nothing stored
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn from_plan_matches_from_dense_masked() {
        let (dense, mask) = sample(7, 24, 40, 0.25);
        let plan = mask.plan();
        let a = CsrMatrix::from_plan(&plan, &dense);
        let b = CsrMatrix::from_dense_masked(&dense, &mask);
        assert_eq!(a, b);
        let vals: Vec<f32> = (0..plan.nnz()).map(|k| k as f32).collect();
        let c = CsrMatrix::from_plan_values(&plan, vals.clone());
        assert_eq!(c.nnz(), plan.nnz());
        let collected: Vec<f32> = (0..24).flat_map(|i| c.row(i).map(|(_, v)| v)).collect();
        assert_eq!(collected, vals);
    }

    #[test]
    fn scale_values_scales() {
        let (dense, mask) = sample(8, 8, 8, 0.5);
        let mut csr = CsrMatrix::from_dense_masked(&dense, &mask);
        let before: Vec<f32> = csr.row(0).map(|(_, v)| v).collect();
        csr.scale_values(2.0);
        let after: Vec<f32> = csr.row(0).map(|(_, v)| v).collect();
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(*a, 2.0 * *b);
        }
    }

    #[test]
    fn spmm_identity() {
        let (dense, _) = sample(6, 8, 8, 1.0);
        let csr = CsrMatrix::from_dense(&dense);
        let got = csr.spmm(&Matrix::eye(8));
        assert!(got.max_abs_diff(&dense) < 1e-6);
    }
}
