//! `DispatchPlan` — the single materialization of one ReCAM mask scan.
//!
//! CPSAA's architectural insight is that *one* ReCAM row-search over the
//! pruning mask drives every downstream engine: the ⟨α, βᵢ⟩ coordinate
//! stream schedules the SDDMM column queues (§4.3), selects the V rows the
//! SpMM engine replicates (§4.4), and shapes the Step 1–4 pipeline. The
//! plan is that search, performed once per mask and shared everywhere:
//!
//! * **CSR topology** (`row_ptr`/`col_idx`, no values) — the coordinate
//!   stream itself; kernels write values straight into it.
//! * **Per-column queue depths** — the SDDMM latency bound of Fig. 8d.
//! * **32×32 tile occupancy** — the crossbar dispatch map of Fig. 19.
//! * **Per-row nnz** — the SpMM V-row replication factor (implicit in
//!   `row_ptr`).
//!
//! Every consumer (attention kernels, `sim::{sddmm, spmm, recam,
//! pruning, pipeline}`, the coordinator) reads these statistics instead of
//! re-walking the mask, so the scan cost is paid once per mask, not once
//! per kernel per layer. New sparsity features (sharding, multi-head
//! fan-out, structured patterns) hook in here.

use super::mask::{BlockCounts, MaskMatrix};

/// Crossbar tile edge of the dispatch fabric (Table 2: 32×32 arrays).
pub const DISPATCH_TILE: usize = 32;

/// Split `0..n` into at most `parts` contiguous ranges of roughly equal
/// total weight (greedy target fill, never an empty range). The one
/// partitioner behind every nnz-balanced split: per-kernel worker
/// dispatch ([`DispatchPlan::partition_rows`]) and batch-parallel shard
/// assignment ([`PlanSet::partition_rows`][super::PlanSet::partition_rows]).
pub(crate) fn partition_by_weights(
    n: usize,
    weight: impl Fn(usize) -> usize,
    parts: usize,
) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    if n == 0 {
        return Vec::new();
    }
    let total: usize = (0..n).map(&weight).sum();
    if parts == 1 || total == 0 {
        return vec![0..n];
    }
    let target = total.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut budget = 0usize;
    for i in 0..n {
        budget += weight(i);
        if budget >= target && i + 1 < n && out.len() + 1 < parts {
            out.push(start..i + 1);
            start = i + 1;
            budget = 0;
        }
    }
    out.push(start..n);
    out
}

/// The precomputed dispatch schedule of one pruning mask.
///
/// The coordinate stream is stored as `u32` — the ⟨α, βᵢ⟩ stream is the
/// hot path's dominant memory traffic (every SDDMM dot and SpMM gather
/// walks it), and the crossbar fabric addresses at most `2^32` columns,
/// so narrowing it halves the bytes the kernels pull per coordinate.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchPlan {
    rows: usize,
    cols: usize,
    /// CSR row pointers: row i's coordinates live at
    /// `col_idx[row_ptr[i]..row_ptr[i+1]]`, ascending.
    row_ptr: Vec<u32>,
    /// Column indices of every '1' cell, row-major (the ⟨α, βᵢ⟩ stream).
    col_idx: Vec<u32>,
    /// Ones per column — the SDDMM per-column input-queue depths.
    col_nnz: Vec<u32>,
    /// Nonzeros per DISPATCH_TILE×DISPATCH_TILE tile.
    blocks: BlockCounts,
}

impl DispatchPlan {
    /// One scan over the mask builds every statistic.
    pub fn build(mask: &MaskMatrix) -> Self {
        let rows = mask.rows();
        let cols = mask.cols();
        assert!(cols <= u32::MAX as usize, "mask wider than the u32 coordinate stream");
        assert!(
            mask.nnz() <= u32::MAX as usize,
            "mask nnz overflows the u32 row-pointer stream"
        );
        let tile_rows = rows.div_ceil(DISPATCH_TILE).max(1);
        let tile_cols = cols.div_ceil(DISPATCH_TILE).max(1);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx: Vec<u32> = Vec::with_capacity(mask.nnz());
        let mut col_nnz = vec![0u32; cols];
        let mut counts = vec![0u32; tile_rows * tile_cols];
        row_ptr.push(0u32);
        for i in 0..rows {
            let tile_row_base = (i / DISPATCH_TILE) * tile_cols;
            for j in mask.row_coords(i) {
                col_idx.push(j as u32);
                col_nnz[j] += 1;
                counts[tile_row_base + j / DISPATCH_TILE] += 1;
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let blocks = BlockCounts { tile_rows, tile_cols, counts };
        Self { rows, cols, row_ptr, col_idx, col_nnz, blocks }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total masked coordinates.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Fraction of ones.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// CSR row pointers (len `rows + 1`), `u32` like the coordinate
    /// stream they index.
    pub fn row_ptr(&self) -> &[u32] {
        &self.row_ptr
    }

    /// Flat column-index stream (len `nnz`).
    pub fn col_idx(&self) -> &[u32] {
        &self.col_idx
    }

    /// Row `i`'s span of the flat coordinate stream, as `usize` bounds
    /// for slicing kernel value buffers.
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize
    }

    /// Column coordinates of row `i` (one ReCAM row-match), ascending.
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.col_idx[self.row_range(i)]
    }

    /// Ones in row `i` — the V-row replication count of output row i.
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Per-column queue depths (the Fig. 8d latency bound).
    pub fn col_queue_depths(&self) -> &[u32] {
        &self.col_nnz
    }

    /// Deepest single-column queue.
    pub fn max_col_queue(&self) -> u64 {
        self.col_nnz.iter().copied().map(u64::from).max().unwrap_or(0)
    }

    /// Deepest queue when `group` adjacent columns colocate behind one
    /// ADC (crossbar-size effect, Fig. 19a): colocated queues serialize,
    /// so the bound is the max over groups of the group's summed depth.
    pub fn grouped_max_queue(&self, group: usize) -> u64 {
        let g = group.max(1);
        self.col_nnz
            .chunks(g)
            .map(|c| c.iter().copied().map(u64::from).sum::<u64>())
            .max()
            .unwrap_or(0)
    }

    /// Tile occupancy over the DISPATCH_TILE×DISPATCH_TILE grid.
    pub fn blocks(&self) -> &BlockCounts {
        &self.blocks
    }

    /// Columns used by any row — the V rows the SpMM method replicates.
    pub fn used_columns(&self) -> Vec<usize> {
        self.col_nnz
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(j, _)| j)
            .collect()
    }

    /// Number of distinct used columns.
    pub fn used_column_count(&self) -> usize {
        self.col_nnz.iter().filter(|&&c| c > 0).count()
    }

    /// Split `0..rows` into at most `parts` contiguous ranges of roughly
    /// equal nnz — the work partition for parallel kernel dispatch and
    /// (via [`PlanSet`][super::PlanSet]) for batch-parallel sharding.
    pub fn partition_rows(&self, parts: usize) -> Vec<std::ops::Range<usize>> {
        partition_by_weights(self.rows, |i| self.row_nnz(i), parts)
    }

    /// The plan filtered to the kept rows and columns — cascade
    /// narrowing's derived schedule. One pass over the existing u32
    /// coordinate stream keeps exactly the coordinates ⟨i, j⟩ with
    /// `keep_rows[i] && keep_cols[j]`; the mask is never rescanned.
    /// Dimensions are preserved (dropped query rows become empty rows,
    /// dropped key columns simply stop appearing), so the narrowed plan
    /// stays drop-in compatible with every kernel, simulator engine,
    /// and shard partitioner. Keeping everything reproduces the plan
    /// exactly (`narrow(all, all) == self`, bit for bit).
    pub fn narrow(&self, keep_rows: &[bool], keep_cols: &[bool]) -> DispatchPlan {
        assert_eq!(keep_rows.len(), self.rows, "keep_rows length");
        assert_eq!(keep_cols.len(), self.cols, "keep_cols length");
        let tile_rows = self.blocks.tile_rows;
        let tile_cols = self.blocks.tile_cols;
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        let mut col_idx: Vec<u32> = Vec::with_capacity(self.nnz());
        let mut col_nnz = vec![0u32; self.cols];
        let mut counts = vec![0u32; tile_rows * tile_cols];
        row_ptr.push(0u32);
        for i in 0..self.rows {
            if keep_rows[i] {
                let tile_row_base = (i / DISPATCH_TILE) * tile_cols;
                for &j in self.row_cols(i) {
                    if keep_cols[j as usize] {
                        col_idx.push(j);
                        col_nnz[j as usize] += 1;
                        counts[tile_row_base + j as usize / DISPATCH_TILE] += 1;
                    }
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        DispatchPlan {
            rows: self.rows,
            cols: self.cols,
            row_ptr,
            col_idx,
            col_nnz,
            blocks: BlockCounts { tile_rows, tile_cols, counts },
        }
    }

    /// The plan restricted to the contiguous row range `rows` — one
    /// shard's view of the batch: local row indices `0..rows.len()`,
    /// full key columns. The CSR topology is carried over (no rescan);
    /// the per-column queue depths and tile occupancy are rebuilt for
    /// the slice, because the shard's chip dispatches only its own
    /// coordinates. Slicing the full range reproduces the plan exactly.
    pub fn slice_rows(&self, rows: std::ops::Range<usize>) -> DispatchPlan {
        assert!(
            rows.start <= rows.end && rows.end <= self.rows,
            "slice {rows:?} of {} rows",
            self.rows
        );
        let n = rows.len();
        let tile_rows = n.div_ceil(DISPATCH_TILE).max(1);
        let tile_cols = self.cols.div_ceil(DISPATCH_TILE).max(1);
        let base = self.row_ptr[rows.start];
        let row_ptr: Vec<u32> =
            self.row_ptr[rows.start..=rows.end].iter().map(|p| p - base).collect();
        let col_idx = self.col_idx[base as usize..self.row_ptr[rows.end] as usize].to_vec();
        let mut col_nnz = vec![0u32; self.cols];
        let mut counts = vec![0u32; tile_rows * tile_cols];
        for i in 0..n {
            let tile_row_base = (i / DISPATCH_TILE) * tile_cols;
            for &j in &col_idx[row_ptr[i] as usize..row_ptr[i + 1] as usize] {
                col_nnz[j as usize] += 1;
                counts[tile_row_base + j as usize / DISPATCH_TILE] += 1;
            }
        }
        DispatchPlan {
            rows: n,
            cols: self.cols,
            row_ptr,
            col_idx,
            col_nnz,
            blocks: BlockCounts { tile_rows, tile_cols, counts },
        }
    }
}

impl MaskMatrix {
    /// Build this mask's [`DispatchPlan`] (one ReCAM scan, shared by every
    /// engine).
    pub fn plan(&self) -> DispatchPlan {
        DispatchPlan::build(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn mask(n: usize, m: usize, density: f64, seed: u64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(seed).mask_matrix(n, m, density))
    }

    #[test]
    fn topology_matches_mask() {
        let m = mask(37, 65, 0.2, 1);
        let p = m.plan();
        assert_eq!((p.rows(), p.cols()), (37, 65));
        assert_eq!(p.nnz(), m.nnz());
        for i in 0..37 {
            assert_eq!(p.row_nnz(i), m.row_nnz(i));
            let cols = p.row_cols(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            for &j in cols {
                assert!(m.get(i, j as usize), "({i},{j}) not set");
            }
            assert_eq!(p.row_range(i).len(), p.row_nnz(i));
        }
    }

    #[test]
    fn column_queues_are_brute_force_counts() {
        let m = mask(48, 48, 0.3, 2);
        let p = m.plan();
        for j in 0..48 {
            let want = (0..48).filter(|&i| m.get(i, j)).count() as u32;
            assert_eq!(p.col_queue_depths()[j], want, "column {j}");
        }
        assert_eq!(p.grouped_max_queue(1), p.max_col_queue());
        assert_eq!(p.grouped_max_queue(48), p.nnz() as u64);
    }

    #[test]
    fn blocks_conserve_mass() {
        let m = mask(64, 64, 0.15, 3);
        let p = m.plan();
        assert_eq!(p.blocks().total(), m.nnz() as u64);
        assert_eq!(p.blocks().counts, m.block_counts(DISPATCH_TILE, DISPATCH_TILE).counts);
    }

    #[test]
    fn used_columns_match_mask() {
        let mut m = MaskMatrix::zeros(4, 8);
        m.set(0, 1, true);
        m.set(3, 1, true);
        m.set(2, 5, true);
        let p = m.plan();
        assert_eq!(p.used_columns(), vec![1, 5]);
        assert_eq!(p.used_column_count(), 2);
    }

    #[test]
    fn empty_and_full_masks() {
        let empty = MaskMatrix::zeros(16, 16).plan();
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.max_col_queue(), 0);
        assert_eq!(empty.density(), 0.0);
        let full = MaskMatrix::ones(16, 16).plan();
        assert_eq!(full.nnz(), 256);
        assert_eq!(full.max_col_queue(), 16);
        assert_eq!(full.density(), 1.0);
    }

    #[test]
    fn partition_covers_rows_contiguously() {
        for (n, density, parts) in [(64, 0.2, 4), (33, 0.0, 3), (16, 1.0, 5), (8, 0.5, 1)] {
            let p = mask(n, n, density, 7).plan();
            let ranges = p.partition_rows(parts);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts.max(1));
            let mut cursor = 0usize;
            for r in &ranges {
                assert_eq!(r.start, cursor);
                assert!(r.end > r.start);
                cursor = r.end;
            }
            assert_eq!(cursor, n);
        }
    }

    #[test]
    fn narrow_keep_all_is_identity() {
        for density in [0.0, 0.15, 1.0] {
            let p = mask(40, 56, density, 11).plan();
            let all_rows = vec![true; 40];
            let all_cols = vec![true; 56];
            assert_eq!(p.narrow(&all_rows, &all_cols), p, "density {density}");
        }
    }

    #[test]
    fn narrow_matches_rebuilt_filtered_mask() {
        let m = mask(48, 64, 0.25, 21);
        let p = m.plan();
        let keep_rows: Vec<bool> = (0..48).map(|i| i % 3 != 0).collect();
        let keep_cols: Vec<bool> = (0..64).map(|j| j % 2 == 0).collect();
        let narrowed = p.narrow(&keep_rows, &keep_cols);
        // dimensions preserved, coordinates filtered
        assert_eq!((narrowed.rows(), narrowed.cols()), (48, 64));
        // the narrowed plan must equal a from-scratch scan of the
        // filtered mask — without ever having rescanned anything
        let mut filtered = MaskMatrix::zeros(48, 64);
        for i in 0..48 {
            for j in 0..64 {
                if m.get(i, j) && keep_rows[i] && keep_cols[j] {
                    filtered.set(i, j, true);
                }
            }
        }
        assert_eq!(narrowed, filtered.plan());
    }

    #[test]
    fn narrow_drops_rows_and_columns() {
        let m = mask(32, 32, 0.5, 22);
        let p = m.plan();
        let mut keep = vec![true; 32];
        keep[5] = false;
        keep[17] = false;
        let narrowed = p.narrow(&keep, &keep);
        assert_eq!(narrowed.row_nnz(5), 0);
        assert_eq!(narrowed.row_nnz(17), 0);
        assert_eq!(narrowed.col_queue_depths()[5], 0);
        assert_eq!(narrowed.col_queue_depths()[17], 0);
        for i in 0..32 {
            for &j in narrowed.row_cols(i) {
                assert!(keep[i] && keep[j as usize]);
                assert!(m.get(i, j as usize));
            }
        }
        assert_eq!(narrowed.blocks().total(), narrowed.nnz() as u64);
        // narrowing is monotone: never grows the stream
        assert!(narrowed.nnz() <= p.nnz());
        // narrowing composes: filtering twice with the same keep sets is
        // a fixpoint (cumulative cascade layers reuse the same stream)
        assert_eq!(narrowed.narrow(&keep, &keep), narrowed);
    }

    #[test]
    fn narrow_empty_keep_empties_the_plan() {
        let p = mask(16, 16, 0.4, 23).plan();
        let none = vec![false; 16];
        let all = vec![true; 16];
        let narrowed = p.narrow(&none, &all);
        assert_eq!(narrowed.nnz(), 0);
        assert_eq!((narrowed.rows(), narrowed.cols()), (16, 16));
        assert_eq!(narrowed.density(), 0.0);
    }

    #[test]
    fn slice_full_range_is_identity() {
        for density in [0.0, 0.15, 1.0] {
            let p = mask(40, 56, density, 11).plan();
            assert_eq!(p.slice_rows(0..40), p, "density {density}");
        }
    }

    #[test]
    fn slice_matches_rebuilt_subplan() {
        let m = mask(48, 64, 0.2, 12);
        let p = m.plan();
        for range in [0..16, 16..48, 7..9, 31..33] {
            let sliced = p.slice_rows(range.clone());
            // Rebuild from the dense rows of the same range: the slice
            // must equal a from-scratch scan of that sub-mask.
            let sub = MaskMatrix::from_dense(
                &m.to_dense().row_block(range.start, range.end),
            );
            assert_eq!(sliced, sub.plan(), "range {range:?}");
        }
    }

    #[test]
    fn slice_topology_and_queues() {
        let m = mask(64, 64, 0.25, 13);
        let p = m.plan();
        let s = p.slice_rows(10..30);
        assert_eq!((s.rows(), s.cols()), (20, 64));
        let want_nnz: usize = (10..30).map(|i| p.row_nnz(i)).sum();
        assert_eq!(s.nnz(), want_nnz);
        for i in 0..20 {
            assert_eq!(s.row_cols(i), p.row_cols(10 + i), "local row {i}");
        }
        for j in 0..64 {
            let want = (10..30).filter(|&i| m.get(i, j)).count() as u32;
            assert_eq!(s.col_queue_depths()[j], want, "column {j}");
        }
        assert_eq!(s.blocks().total(), want_nnz as u64);
    }

    #[test]
    fn partition_balances_nnz() {
        let p = mask(320, 320, 0.1, 9).plan();
        let ranges = p.partition_rows(4);
        let loads: Vec<usize> =
            ranges.iter().map(|r| r.clone().map(|i| p.row_nnz(i)).sum()).collect();
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        assert!(max < 2 * min.max(1) + p.cols(), "imbalanced: {loads:?}");
    }
}
