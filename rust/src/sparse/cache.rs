//! Content-addressed plan cache — skip the ReCAM scan for repeated
//! request shapes.
//!
//! A batch's layer-0 [`PlanSet`] is a pure function of the payload bits
//! (mask generation reads `x` and the frozen mask weights, the scan
//! reads only the masks), so two batches with bit-identical payloads
//! build bit-identical plans. The serving layer exploits that with a
//! bounded LRU keyed by a content hash of the payload: a hit returns
//! the shared `Arc<PlanSet>` and the batch skips mask generation and
//! the scan entirely; a miss builds (or prefetches) the plans and
//! inserts them for the next identical shape.
//!
//! Bit-identity is the hard contract: the cache key is a 128-bit hash
//! (two independent FNV-1a-64 streams) over the exact `f32` bit
//! patterns plus every shape input, so a collision would need two
//! distinct payloads agreeing on both 64-bit digests — negligible at
//! any realistic cache size — and a hit hands back a plan set that is
//! bitwise equal to what a rebuild would produce, keeping responses
//! identical whether they were served from the cache or not.

use std::sync::Arc;

use super::planset::PlanSet;
use super::prune::PruneConfig;
use crate::tensor::Matrix;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Second-stream seed: a different offset basis makes the two digests
/// independent enough that a simultaneous collision needs 2^128 luck.
const FNV_OFFSET_B: u64 = 0x6c62_272e_07bb_0142;

/// Two independent FNV-1a-64 digests over one byte stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Digest(u64, u64);

impl Digest {
    fn new() -> Self {
        Digest(FNV_OFFSET, FNV_OFFSET_B)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ u64::from(b)).wrapping_mul(FNV_PRIME);
            self.1 = (self.1 ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
    }

    fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }
}

/// Content address of one batch's layer-0 plan set: the payload shape
/// in the clear plus the 128-bit digest of everything the plans are a
/// function of — payload `f32` bit patterns, row/column counts, head
/// count, and the prune config (as its canonical string form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanKey {
    rows: usize,
    cols: usize,
    heads: usize,
    hash: (u64, u64),
}

impl PlanKey {
    /// Key the batch payload `x` under `heads` heads and `prune`.
    pub fn for_batch(x: &Matrix, heads: usize, prune: &PruneConfig) -> Self {
        let mut d = Digest::new();
        d.write_u64(x.rows() as u64);
        d.write_u64(x.cols() as u64);
        d.write_u64(heads as u64);
        d.write(prune.to_string().as_bytes());
        for &v in x.data() {
            d.write(&v.to_bits().to_le_bytes());
        }
        Self { rows: x.rows(), cols: x.cols(), heads, hash: (d.0, d.1) }
    }
}

/// Bounded move-to-front LRU of `PlanKey → Arc<PlanSet>`. Capacity 0
/// disables caching (every lookup misses, inserts are dropped). The
/// entry list is a plain `Vec` — capacities are small (default 32) and
/// the linear probe is trivially cheaper than one mask scan it saves.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    /// Most-recently used first.
    entries: Vec<(PlanKey, Arc<PlanSet>)>,
}

impl PlanCache {
    pub fn new(cap: usize) -> Self {
        Self { cap, entries: Vec::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The cached plans for `key`, refreshed to most-recently used.
    pub fn get(&mut self, key: &PlanKey) -> Option<Arc<PlanSet>> {
        let idx = self.entries.iter().position(|(k, _)| k == key)?;
        let entry = self.entries.remove(idx);
        let plans = entry.1.clone();
        self.entries.insert(0, entry);
        Some(plans)
    }

    /// Insert (or refresh) `key`, evicting the least-recently used
    /// entry past capacity.
    pub fn insert(&mut self, key: PlanKey, plans: Arc<PlanSet>) {
        if self.cap == 0 {
            return;
        }
        if let Some(idx) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(idx);
        }
        self.entries.insert(0, (key, plans));
        self.entries.truncate(self.cap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MaskMatrix;
    use crate::tensor::SeededRng;

    fn plans(seed: u64) -> Arc<PlanSet> {
        let mut rng = SeededRng::new(seed);
        let masks = vec![MaskMatrix::from_dense(&rng.mask_matrix(8, 8, 0.3))];
        Arc::new(PlanSet::build(&masks))
    }

    fn key(seed: u64) -> PlanKey {
        let x = SeededRng::new(seed).normal_matrix(8, 16, 1.0);
        PlanKey::for_batch(&x, 2, &PruneConfig::Static)
    }

    #[test]
    fn key_is_a_function_of_the_payload_bits() {
        let x = SeededRng::new(5).normal_matrix(8, 16, 1.0);
        let a = PlanKey::for_batch(&x, 2, &PruneConfig::Static);
        let b = PlanKey::for_batch(&x.clone(), 2, &PruneConfig::Static);
        assert_eq!(a, b, "identical payloads must collide on purpose");
        // one flipped mantissa bit changes the key
        let mut data = x.data().to_vec();
        data[3] = f32::from_bits(data[3].to_bits() ^ 1);
        let y = Matrix::from_vec(8, 16, data);
        assert_ne!(PlanKey::for_batch(&y, 2, &PruneConfig::Static), a);
        // so do the shape inputs the plans depend on
        assert_ne!(PlanKey::for_batch(&x, 4, &PruneConfig::Static), a);
        assert_ne!(PlanKey::for_batch(&x, 2, &PruneConfig::cascade(0.5)), a);
    }

    #[test]
    fn lru_hits_refresh_and_capacity_evicts_the_tail() {
        let mut cache = PlanCache::new(2);
        let (ka, kb, kc) = (key(1), key(2), key(3));
        cache.insert(ka, plans(1));
        cache.insert(kb, plans(2));
        assert_eq!(cache.len(), 2);
        // touching A makes B the eviction candidate...
        assert!(cache.get(&ka).is_some());
        cache.insert(kc, plans(3));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&kb).is_none(), "B was least-recently used");
        assert!(cache.get(&ka).is_some());
        assert!(cache.get(&kc).is_some());
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut cache = PlanCache::new(2);
        let (ka, kb) = (key(1), key(2));
        cache.insert(ka, plans(1));
        cache.insert(kb, plans(2));
        cache.insert(ka, plans(1));
        assert_eq!(cache.len(), 2);
        // A was refreshed to the front, so B evicts next
        cache.insert(key(3), plans(3));
        assert!(cache.get(&ka).is_some());
        assert!(cache.get(&kb).is_none());
    }

    #[test]
    fn zero_capacity_disables_the_cache() {
        let mut cache = PlanCache::new(0);
        cache.insert(key(1), plans(1));
        assert!(cache.is_empty());
        assert!(cache.get(&key(1)).is_none());
        assert_eq!(cache.capacity(), 0);
    }

    #[test]
    fn cached_plans_are_bitwise_equal_to_a_rebuild() {
        // The bit-identity contract at the cache layer: what comes out
        // of the cache compares equal (PartialEq is structural over the
        // full CSR topology) to building the same plans from scratch.
        let mut cache = PlanCache::new(4);
        let k = key(9);
        cache.insert(k, plans(9));
        let cached = cache.get(&k).unwrap();
        assert_eq!(*cached, *plans(9));
    }
}
