//! Binary pruning mask — the ReCAM scheduler's contents.

use crate::tensor::Matrix;

/// Bit-packed binary mask matrix (the G matrix of eq. 1).
///
/// One `u64` word per 64 columns; row-major. The ReCAM array of the paper
/// performs a parallel row search that emits the coordinates of '1' cells —
/// [`MaskMatrix::row_coords`] reproduces exactly that ⟨α, βᵢ⟩ stream.
#[derive(Clone, Debug, PartialEq)]
pub struct MaskMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl MaskMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let words_per_row = cols.div_ceil(64);
        Self { rows, cols, words_per_row, bits: vec![0; rows * words_per_row] }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, true);
            }
        }
        m
    }

    /// Interpret a dense f32 matrix as a mask (non-zero ⇒ 1), the format
    /// the HLO artifacts exchange masks in.
    pub fn from_dense(m: &Matrix) -> Self {
        let mut out = Self::zeros(m.rows(), m.cols());
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                if m.get(i, j) != 0.0 {
                    out.set(i, j, true);
                }
            }
        }
        out
    }

    /// Back to a dense 0/1 f32 matrix.
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.get(i, j) {
                    out.set(i, j, 1.0);
                }
            }
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.rows && j < self.cols);
        let w = self.bits[i * self.words_per_row + j / 64];
        (w >> (j % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        debug_assert!(i < self.rows && j < self.cols);
        let w = &mut self.bits[i * self.words_per_row + j / 64];
        if v {
            *w |= 1 << (j % 64);
        } else {
            *w &= !(1 << (j % 64));
        }
    }

    /// Number of ones in row `i` — one ReCAM row-match popcount.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_words(i).iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Total ones.
    pub fn nnz(&self) -> usize {
        (0..self.rows).map(|i| self.row_nnz(i)).sum()
    }

    /// Fraction of ones (the paper's "sparsity ≈ 0.1" is this density).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    #[inline]
    fn row_words(&self, i: usize) -> &[u64] {
        &self.bits[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// The ⟨α, βᵢ⟩ coordinate stream of one ReCAM row search: column
    /// indices of the '1' cells of row `i`, ascending.
    pub fn row_coords(&self, i: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.row_nnz(i));
        for (wi, &word) in self.row_words(i).iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                out.push(wi * 64 + b);
                w &= w - 1;
            }
        }
        out
    }

    /// Per-tile population counts — the ReCAM block summary used by the
    /// SDDMM/SpMM engines and mirrored by `kernels.block_mask_counts`.
    pub fn block_counts(&self, bm: usize, bn: usize) -> BlockCounts {
        assert!(bm > 0 && bn > 0);
        let tr = self.rows.div_ceil(bm);
        let tc = self.cols.div_ceil(bn);
        let mut counts = vec![0u32; tr * tc];
        for i in 0..self.rows {
            for j in self.row_coords(i) {
                counts[(i / bm) * tc + j / bn] += 1;
            }
        }
        BlockCounts { tile_rows: tr, tile_cols: tc, counts }
    }

    /// Columns used by *any* row — the set of V rows the SpMM method must
    /// replicate (§4.4).
    pub fn used_columns(&self) -> Vec<usize> {
        let mut used = vec![false; self.cols];
        for i in 0..self.rows {
            for j in self.row_coords(i) {
                used[j] = true;
            }
        }
        used.iter().enumerate().filter(|(_, &u)| u).map(|(j, _)| j).collect()
    }
}

/// Tile-level nonzero counts of a mask.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockCounts {
    pub tile_rows: usize,
    pub tile_cols: usize,
    pub counts: Vec<u32>,
}

impl BlockCounts {
    pub fn get(&self, ti: usize, tj: usize) -> u32 {
        self.counts[ti * self.tile_cols + tj]
    }

    /// Number of non-empty tiles — the VMM dispatch count of the SDDMM
    /// engine.
    pub fn nonzero_tiles(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().map(|&c| c as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn set_get_roundtrip() {
        let mut m = MaskMatrix::zeros(4, 100);
        m.set(2, 63, true);
        m.set(2, 64, true);
        m.set(3, 99, true);
        assert!(m.get(2, 63) && m.get(2, 64) && m.get(3, 99));
        assert!(!m.get(0, 0));
        m.set(2, 63, false);
        assert!(!m.get(2, 63));
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn row_coords_sorted_and_complete() {
        let dense = SeededRng::new(1).mask_matrix(16, 130, 0.3);
        let m = MaskMatrix::from_dense(&dense);
        for i in 0..16 {
            let coords = m.row_coords(i);
            assert!(coords.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(coords.len(), m.row_nnz(i));
            for &j in &coords {
                assert_eq!(dense.get(i, j), 1.0);
            }
        }
    }

    #[test]
    fn dense_roundtrip() {
        let dense = SeededRng::new(2).mask_matrix(33, 65, 0.2);
        assert_eq!(MaskMatrix::from_dense(&dense).to_dense(), dense);
    }

    #[test]
    fn block_counts_conserve() {
        let dense = SeededRng::new(3).mask_matrix(64, 64, 0.15);
        let m = MaskMatrix::from_dense(&dense);
        let bc = m.block_counts(32, 32);
        assert_eq!(bc.total(), m.nnz() as u64);
        assert_eq!((bc.tile_rows, bc.tile_cols), (2, 2));
    }

    #[test]
    fn block_counts_ragged_edges() {
        let m = MaskMatrix::ones(33, 65);
        let bc = m.block_counts(32, 32);
        assert_eq!((bc.tile_rows, bc.tile_cols), (2, 3));
        assert_eq!(bc.total(), 33 * 65);
        assert_eq!(bc.get(1, 2), 1); // single cell in the corner tile
    }

    #[test]
    fn used_columns_subset() {
        let mut m = MaskMatrix::zeros(4, 8);
        m.set(0, 1, true);
        m.set(3, 1, true);
        m.set(2, 5, true);
        assert_eq!(m.used_columns(), vec![1, 5]);
    }

    #[test]
    fn ones_density() {
        assert_eq!(MaskMatrix::ones(10, 10).density(), 1.0);
        assert_eq!(MaskMatrix::zeros(10, 10).density(), 0.0);
    }
}
